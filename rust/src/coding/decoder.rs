//! Decoder: recover `A·x` from any `k` coded inner products.
//!
//! The master receives pairs `(global_row_index, ⟨Ã_row, x⟩)`. Since
//! `⟨Ã_i, x⟩ = G_i · (A x)`, collecting a row set `B` with `|B| = k` yields
//! the linear system `G_B · z = y_B` whose solution is `z = A·x`.

use crate::coding::{Generator, Matrix};
use crate::{Error, Result};

/// Decoder bound to a generator.
#[derive(Clone, Debug)]
pub struct Decoder {
    generator: Generator,
}

impl Decoder {
    /// Wrap a generator.
    pub fn new(generator: Generator) -> Self {
        Decoder { generator }
    }

    /// Decode `A·x` from received `(row_index, value)` pairs.
    ///
    /// Uses the first `k` received rows; if that submatrix is singular
    /// (probability-zero for the random construction, impossible for
    /// Vandermonde), later rows are substituted in one at a time.
    pub fn decode(&self, received: &[(usize, f64)]) -> Result<Vec<f64>> {
        let k = self.generator.k();
        if received.len() < k {
            return Err(Error::Decode(format!(
                "need {k} rows, got {}",
                received.len()
            )));
        }
        // Reject duplicate / out-of-range indices up front.
        let mut seen = vec![false; self.generator.n()];
        for &(idx, _) in received {
            if idx >= self.generator.n() {
                return Err(Error::Decode(format!("row index {idx} out of range")));
            }
            if seen[idx] {
                return Err(Error::Decode(format!("duplicate row index {idx}")));
            }
            seen[idx] = true;
        }

        let active: Vec<(usize, f64)> = received[..k].to_vec();

        // Vandermonde generators decode via Björck–Pereyra (O(k²), far more
        // accurate than LU on the same ill-conditioned system): the decode
        // IS polynomial interpolation on the received rows' nodes.
        if let Some(nodes) = self.generator.nodes() {
            let xs: Vec<f64> = active.iter().map(|&(i, _)| nodes[i]).collect();
            let ys: Vec<f64> = active.iter().map(|&(_, v)| v).collect();
            return crate::coding::bjorck_pereyra::solve_vandermonde(&xs, &ys)
                .map_err(|e| Error::Decode(format!("BP solve failed: {e}")));
        }

        let mut active = active;
        let mut spare = k; // next candidate in `received` to swap in
        loop {
            let rows: Vec<usize> = active.iter().map(|&(i, _)| i).collect();
            let sub = self.generator.submatrix(&rows);
            match sub.lu() {
                Ok(lu) => {
                    let y: Vec<f64> = active.iter().map(|&(_, v)| v).collect();
                    return lu.solve(&y);
                }
                Err(_) if spare < received.len() => {
                    // Replace the row most likely to be the dependent one:
                    // rotate through positions deterministically.
                    let pos = spare - k;
                    active[pos % k] = received[spare];
                    spare += 1;
                }
                Err(e) => {
                    return Err(Error::Decode(format!(
                        "no invertible k-subset among received rows: {e}"
                    )))
                }
            }
        }
    }

    /// Convenience for tests: decode and compare against ground truth,
    /// returning the max absolute error.
    pub fn decode_error(&self, received: &[(usize, f64)], truth: &[f64]) -> Result<f64> {
        let z = self.decode(received)?;
        if z.len() != truth.len() {
            return Err(Error::Decode("length mismatch vs truth".into()));
        }
        Ok(z.iter()
            .zip(truth)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max))
    }

    /// The underlying generator.
    pub fn generator(&self) -> &Generator {
        &self.generator
    }
}

/// End-to-end helper: encode, evaluate inner products on a row subset and
/// decode back (used by tests and the simulator's correctness checks).
pub fn roundtrip_check(
    gen: &Generator,
    a: &Matrix,
    x: &[f64],
    rows: &[usize],
) -> Result<f64> {
    let coded = gen.matrix().matmul(a);
    let truth = a.matvec(x);
    let received: Vec<(usize, f64)> = rows
        .iter()
        .map(|&i| {
            let mut acc = 0.0;
            for (av, xv) in coded.row(i).iter().zip(x) {
                acc += av * xv;
            }
            (i, acc)
        })
        .collect();
    Decoder::new(gen.clone()).decode_error(&received, &truth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::GeneratorKind;
    use crate::math::Rng;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn decode_from_systematic_rows_is_exact() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let a = random_matrix(4, 6, 2);
        let x: Vec<f64> = (0..6).map(|i| (i as f64).sin() + 1.0).collect();
        let err = roundtrip_check(&gen, &a, &x, &[0, 1, 2, 3]).unwrap();
        assert!(err < 1e-12, "err={err}");
    }

    #[test]
    fn decode_from_parity_rows() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let a = random_matrix(4, 6, 3);
        let x: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let err = roundtrip_check(&gen, &a, &x, &[6, 7, 8, 9]).unwrap();
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn decode_from_mixed_rows_many_subsets() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 16, 6, 11).unwrap();
        let a = random_matrix(6, 4, 5);
        let x = vec![0.3, -1.2, 2.0, 0.7];
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let mut all: Vec<usize> = (0..16).collect();
            rng.shuffle(&mut all);
            let rows = &all[..6];
            let err = roundtrip_check(&gen, &a, &x, rows).unwrap();
            assert!(err < 1e-8, "rows {rows:?} err={err}");
        }
    }

    #[test]
    fn vandermonde_decode_small_k() {
        let gen = Generator::new(GeneratorKind::Vandermonde, 9, 5, 0).unwrap();
        let a = random_matrix(5, 3, 8);
        let x = vec![1.0, -1.0, 0.5];
        for rows in [[0, 1, 2, 3, 4], [4, 5, 6, 7, 8], [0, 2, 4, 6, 8]] {
            let err = roundtrip_check(&gen, &a, &x, &rows).unwrap();
            assert!(err < 1e-7, "rows {rows:?} err={err}");
        }
    }

    #[test]
    fn vandermonde_decode_larger_k_via_bjorck_pereyra() {
        // LU on a k=32 Chebyshev Vandermonde produces O(100) errors (see
        // the ablation bench); the BP decode path stays accurate.
        let gen = Generator::new(GeneratorKind::Vandermonde, 48, 32, 0).unwrap();
        let a = random_matrix(32, 3, 12);
        let x = vec![0.5, -1.0, 2.0];
        let rows: Vec<usize> = (8..40).collect(); // mixed middle rows
        let err = roundtrip_check(&gen, &a, &x, &rows).unwrap();
        // The decode is still an ill-conditioned interpolation (the row
        // subset is not itself a Chebyshev grid), but BP keeps the error
        // ~3 orders below what LU produced at this k (O(100), see the
        // ablation bench).
        assert!(err < 0.05, "err={err}");
    }

    #[test]
    fn decode_needs_k_rows() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let dec = Decoder::new(gen);
        assert!(dec.decode(&[(0, 1.0), (1, 2.0), (2, 3.0)]).is_err());
    }

    #[test]
    fn decode_rejects_duplicates_and_out_of_range() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let dec = Decoder::new(gen);
        let dup = [(0, 1.0), (0, 1.0), (1, 2.0), (2, 3.0)];
        assert!(dec.decode(&dup).is_err());
        let oor = [(0, 1.0), (1, 2.0), (2, 3.0), (99, 4.0)];
        assert!(dec.decode(&oor).is_err());
    }

    #[test]
    fn extra_rows_are_harmless() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 12, 4, 21).unwrap();
        let a = random_matrix(4, 5, 22);
        let x = vec![2.0, 0.0, -1.0, 1.0, 3.0];
        let err = roundtrip_check(&gen, &a, &x, &[1, 3, 5, 7, 9, 11]).unwrap();
        assert!(err < 1e-9);
    }

    #[test]
    fn decode_at_moderate_k_stays_stable() {
        // Conditioning check for the random construction at k=128.
        let k = 128;
        let n = 192;
        let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 33).unwrap();
        let a = random_matrix(k, 8, 34);
        let x = vec![1.0; 8];
        // All-parity decode (worst case for conditioning).
        let rows: Vec<usize> = (n - k..n).collect();
        let err = roundtrip_check(&gen, &a, &x, &rows).unwrap();
        assert!(err < 1e-6, "err={err}");
    }
}
