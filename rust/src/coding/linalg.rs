//! Dense linear-algebra substrate (no external numerics crates).
//!
//! Row-major `f64` matrices with the operations the coding layer needs:
//! matmul, matvec, LU decomposition with partial pivoting, solve, and a
//! condition-number estimate for decode diagnostics.

use crate::runtime::pool::WorkPool;
use crate::{Error, Result};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major vec. Panics on size mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "size mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Immutable view of a row.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Append one row (bitwise copy). The rateless stream grows the coded
    /// matrix this way — existing rows are never moved relative to each
    /// other, only the backing vec extends. Errors on width mismatch.
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        if row.len() != self.cols {
            return Err(Error::InvalidSpec(format!(
                "push_row width {} on a {}-column matrix",
                row.len(),
                self.cols
            )));
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
        Ok(())
    }

    /// Extract the submatrix made of the given rows (in order).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (oi, &i) in idx.iter().enumerate() {
            assert!(i < self.rows, "row index {i} out of bounds");
            out.data[oi * self.cols..(oi + 1) * self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Matrix–vector product `self · x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "dimension mismatch");
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// Matrix product `self · other` on the shared global
    /// [`WorkPool`] — parallel when the product is big enough to amortize
    /// pool dispatch, inline otherwise, bit-identical either way.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        self.matmul_on(other, WorkPool::global_ref())
    }

    /// Cache-blocked, register-tiled matrix product executed on `pool`.
    ///
    /// The kernel ([`matmul_block_micro`]) tiles the i-k-j loop so a
    /// `MM_KC × MM_JC` block of `other` stays resident in cache across a
    /// sweep of `self`'s rows, and partitions output *rows* into
    /// pool tasks sized by a per-task FLOP granularity
    /// ([`MM_TASK_FLOPS`]). Per output element the `k`-summation order is
    /// fixed (ascending), so the result is bit-identical for every tile
    /// shape, task split, and pool size.
    pub fn matmul_on(&self, other: &Matrix, pool: &WorkPool) -> Matrix {
        self.matmul_streams(other, pool, pool.threads())
    }

    /// Pre-pool compatibility shim: `threads` now only caps the task
    /// split; execution happens on the shared global [`WorkPool`] (no
    /// per-call thread spawns). `0` = the pool's full parallelism.
    ///
    /// Migration: `a.matmul_on(&b, &pool)` with a
    /// [`crate::runtime::pool::PoolHandle`] (or plain [`Matrix::matmul`]
    /// for the global pool).
    #[deprecated(
        since = "0.3.0",
        note = "use matmul_on with a runtime::pool::WorkPool handle \
                (or matmul() for the global pool)"
    )]
    pub fn matmul_blocked(&self, other: &Matrix, threads: usize) -> Matrix {
        let pool = WorkPool::global_ref();
        let cap = if threads == 0 { pool.threads() } else { threads };
        self.matmul_streams(other, pool, cap)
    }

    /// Shared engine: split output rows into `<= max_streams` tasks of at
    /// least [`MM_TASK_FLOPS`] each and run them on `pool` (crate-visible
    /// so the encoder can cap concurrency without a dedicated pool).
    pub(crate) fn matmul_streams(
        &self,
        other: &Matrix,
        pool: &WorkPool,
        max_streams: usize,
    ) -> Matrix {
        assert_eq!(self.cols, other.rows, "dimension mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        if self.rows == 0 || other.cols == 0 {
            return out;
        }
        // Per-task granularity check (not a flat threshold): parallelize
        // only into tasks that individually carry enough FLOPs to amortize
        // pool dispatch, so small products stay inline with zero overhead
        // and medium ones get exactly as many streams as they can feed.
        let flops = self
            .rows
            .saturating_mul(self.cols)
            .saturating_mul(other.cols);
        let tasks = (flops / MM_TASK_FLOPS)
            .clamp(1, max_streams.max(1))
            .min(self.rows);
        // `tasks == 1` runs inline on the calling thread (scope_run's
        // degenerate path) — still visible in the pool's region counters.
        let rows_per = self.rows.div_ceil(tasks);
        let (kdim, n) = (self.cols, other.cols);
        pool.run_chunks_mut(&mut out.data, rows_per * n, |t, out_rows| {
            let m = out_rows.len() / n;
            let a_rows = &self.data[t * rows_per * kdim..][..m * kdim];
            matmul_block_micro(m, kdim, n, a_rows, &other.data, out_rows);
        });
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Max-abs entry (used in error norms).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Infinity norm (max row sum).
    pub fn norm_inf(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|x| x.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// LU-factorize (square) and return the factorization.
    pub fn lu(&self) -> Result<Lu> {
        Lu::factor(self)
    }

    /// Solve `self · x = b` for square `self`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        self.lu()?.solve(b)
    }
}

/// `k`-dimension tile: one tile of `other` rows (`MM_KC × MM_JC` doubles =
/// 256 KiB at the defaults) stays resident in L2 across a sweep of `self`'s
/// rows, while the active `other` row and output row segment (4 KiB each)
/// stream through L1.
const MM_KC: usize = 64;
/// `j`-dimension tile width.
const MM_JC: usize = 512;
/// Register-tile height: rows of `self` processed together so each loaded
/// `other` row feeds [`MM_MR`] accumulator streams. The microkernel body
/// is hand-unrolled to exactly this height — change both together.
const MM_MR: usize = 4;
/// Minimum FLOPs per parallel task. With the persistent [`WorkPool`] the
/// cost of going parallel is a channel push + an atomic claim (~ a few µs),
/// not a per-call thread spawn (~ tens of µs), so the profitable crossover
/// sits near ~128 KFLOP of scalar work per task — way below the old flat
/// 1 MFLOP spawn threshold that gated the whole *product*. Deriving the
/// task count as `flops / MM_TASK_FLOPS` makes small matrices stay inline
/// (no latency regression) while medium ones split into exactly as many
/// streams as they can keep busy.
const MM_TASK_FLOPS: usize = 1 << 17;

/// Register-blocked microkernel: the same `MM_KC × MM_JC` cache tiling as
/// [`matmul_block`], with `self`'s rows additionally processed in
/// [`MM_MR`]-row register tiles. Each loaded `b` row then feeds four
/// independent accumulator streams over a bounds-check-free inner loop
/// (every slice is pre-cut to the tile width `w`, so LLVM proves the
/// indices in-range and autovectorizes the four fused update streams).
///
/// Bit-identity: per output element the `k`-summation order is ascending,
/// exactly as in [`matmul_block`]. The only op-sequence difference is that
/// a register tile with *some* nonzero `a` entries also adds the
/// `0.0 · b` products of its zero entries, which scalar [`matmul_block`]
/// skips — and `x + (±0.0 · b)` is bitwise `x` for every finite `b`
/// (accumulators start at `+0.0` and can never become `-0.0`), so results
/// are byte-equal for all finite inputs. Non-finite inputs (where
/// `0 · ∞ = NaN` makes the skip observable) are outside the coding
/// layer's domain; `microkernel_bit_identical_to_scalar_fallback` in the
/// test module pins the finite-input equivalence.
fn matmul_block_micro(
    m: usize,
    kdim: usize,
    n: usize,
    a: &[f64],
    b: &[f64],
    out: &mut [f64],
) {
    let m_tiled = m - m % MM_MR;
    for jc in (0..n).step_by(MM_JC) {
        let jhi = (jc + MM_JC).min(n);
        let w = jhi - jc;
        for kc in (0..kdim).step_by(MM_KC) {
            let khi = (kc + MM_KC).min(kdim);
            let mut i = 0usize;
            while i < m_tiled {
                let (r0, rest) = out[i * n..(i + MM_MR) * n].split_at_mut(n);
                let (r1, rest) = rest.split_at_mut(n);
                let (r2, r3) = rest.split_at_mut(n);
                let o0 = &mut r0[jc..jhi];
                let o1 = &mut r1[jc..jhi];
                let o2 = &mut r2[jc..jhi];
                let o3 = &mut r3[jc..jhi];
                for kk in kc..khi {
                    let a0 = a[i * kdim + kk];
                    let a1 = a[(i + 1) * kdim + kk];
                    let a2 = a[(i + 2) * kdim + kk];
                    let a3 = a[(i + 3) * kdim + kk];
                    // Whole-tile zero skip: systematic generators are
                    // mostly identity rows, and an all-zero column of the
                    // register tile contributes nothing (bit-exactly).
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n + jc..kk * n + jhi];
                    for j in 0..w {
                        let bv = brow[j];
                        o0[j] += a0 * bv;
                        o1[j] += a1 * bv;
                        o2[j] += a2 * bv;
                        o3[j] += a3 * bv;
                    }
                }
                i += MM_MR;
            }
        }
    }
    // Remainder rows (< MM_MR): the scalar fallback kernel, whose
    // per-element summation order is the same ascending-k sequence.
    if m_tiled < m {
        matmul_block(
            m - m_tiled,
            kdim,
            n,
            &a[m_tiled * kdim..],
            b,
            &mut out[m_tiled * n..],
        );
    }
}

/// Tiled i-k-j kernel over raw row-major slices: `out (m×n) += a (m×kdim) ·
/// b (kdim×n)`. `out` must come in zeroed. For each output element the
/// contributions are accumulated in ascending `k` order (tiles ascend, and
/// `kk` ascends within a tile), so results match the naive loop bit for bit.
/// Kept as the scalar reference the register-blocked
/// [`matmul_block_micro`] is asserted bit-identical against.
fn matmul_block(m: usize, kdim: usize, n: usize, a: &[f64], b: &[f64], out: &mut [f64]) {
    for jc in (0..n).step_by(MM_JC) {
        let jhi = (jc + MM_JC).min(n);
        for kc in (0..kdim).step_by(MM_KC) {
            let khi = (kc + MM_KC).min(kdim);
            for i in 0..m {
                let arow = &a[i * kdim..(i + 1) * kdim];
                let orow = &mut out[i * n + jc..i * n + jhi];
                for (kk, &av) in arow.iter().enumerate().take(khi).skip(kc) {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n + jc..kk * n + jhi];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

/// Register-tile width of the sparse-row × dense-matrix microkernel: one
/// [`SPMM_NR`]-wide accumulator strip stays resident in registers across a
/// row's whole nonzero sweep, so each loaded nonzero feeds [`SPMM_NR`]
/// independent fused update streams (the sparse analogue of [`MM_MR`]).
const SPMM_NR: usize = 8;

/// Compressed-sparse-row (CSR) matrix over `f64`.
///
/// The sparse mirror of [`Matrix`] for the coding layer: a sparse
/// generator (`coding::Generator` with a
/// [`crate::coding::GeneratorKind::SparseParity`] construction) keeps its
/// nonzeros here so the encode `Ã = G·A` costs O(nnz·d) instead of
/// O(n·k·d). Nonzeros are stored row-major with **ascending column order
/// inside every row** — that ordering *is* the summation order of every
/// kernel below, which is what makes the results reproducible and
/// bit-identical to the dense kernels (see [`CsrMatrix::matmul_on`]).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row pointers: row `i`'s nonzeros live at `indptr[i]..indptr[i+1]`.
    indptr: Vec<usize>,
    /// Column index of each nonzero, ascending within every row.
    indices: Vec<usize>,
    /// Value of each nonzero.
    vals: Vec<f64>,
}

impl CsrMatrix {
    /// Build from raw CSR parts, validating the invariants every kernel
    /// relies on: `indptr` has `rows + 1` monotone entries ending at
    /// `indices.len()`, `indices.len() == vals.len()`, and each row's
    /// column indices are strictly ascending and in-bounds.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        vals: Vec<f64>,
    ) -> Result<CsrMatrix> {
        if indptr.len() != rows + 1 {
            return Err(Error::Numerical(format!(
                "CSR indptr has {} entries for {} rows (need rows + 1)",
                indptr.len(),
                rows
            )));
        }
        if indices.len() != vals.len() {
            return Err(Error::Numerical(format!(
                "CSR has {} column indices but {} values",
                indices.len(),
                vals.len()
            )));
        }
        if indptr[0] != 0 || indptr[rows] != indices.len() {
            return Err(Error::Numerical(format!(
                "CSR indptr must span 0..={} (got {}..={})",
                indices.len(),
                indptr[0],
                indptr[rows]
            )));
        }
        for r in 0..rows {
            let (lo, hi) = (indptr[r], indptr[r + 1]);
            if lo > hi {
                return Err(Error::Numerical(format!(
                    "CSR indptr decreases at row {r}"
                )));
            }
            let row_cols = &indices[lo..hi];
            if row_cols.windows(2).any(|w| w[0] >= w[1]) {
                return Err(Error::Numerical(format!(
                    "CSR row {r} columns not strictly ascending"
                )));
            }
            if row_cols.last().is_some_and(|&c| c >= cols) {
                return Err(Error::Numerical(format!(
                    "CSR row {r} column out of bounds (cols = {cols})"
                )));
            }
        }
        Ok(CsrMatrix { rows, cols, indptr, indices, vals })
    }

    /// Compress a dense matrix, dropping entries that compare equal to
    /// zero (`-0.0` included — adding `±0.0` to an accumulator that is
    /// never `-0.0` is a bitwise no-op, so the drop is exact; see
    /// [`CsrMatrix::matmul_on`]).
    pub fn from_dense(m: &Matrix) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(m.rows() + 1);
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        indptr.push(0);
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j);
                    vals.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows: m.rows(), cols: m.cols(), indptr, indices, vals }
    }

    /// Expand back to a dense [`Matrix`].
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            for (&j, &v) in self.indices[lo..hi].iter().zip(&self.vals[lo..hi]) {
                out[(i, j)] = v;
            }
        }
        out
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row `i`'s nonzeros as parallel `(columns, values)` slices
    /// (columns ascending).
    pub fn row_entries(&self, i: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.vals[lo..hi])
    }

    /// Sparse matrix–vector product `self · x`, accumulating each row in
    /// stored (ascending-column) order — the same per-element order as
    /// [`Matrix::matvec`] with the zero terms elided, so results are
    /// bit-identical for finite inputs.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "dimension mismatch");
        let mut y = vec![0.0; self.rows];
        for (i, out) in y.iter_mut().enumerate() {
            let (cols, vals) = self.row_entries(i);
            let mut acc = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            *out = acc;
        }
        y
    }

    /// Sparse × dense product `self · other` on the shared global
    /// [`WorkPool`].
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        self.matmul_on(other, WorkPool::global_ref())
    }

    /// Register-blocked sparse × dense matrix product executed on `pool` —
    /// the O(nnz·d) encode kernel behind sparse generators.
    ///
    /// Output rows are partitioned into pool tasks exactly like the dense
    /// kernel ([`Matrix::matmul_on`]): the task split is derived from a
    /// per-task FLOP granularity ([`MM_TASK_FLOPS`], with FLOPs estimated
    /// as `nnz · other.cols`), each task owns a contiguous strip of output
    /// rows, and the reduction inside one output element is a serial sweep
    /// of that row's nonzeros in stored ascending-column order
    /// ([`spmm_row`]). The pool size and task split choose only *who*
    /// computes a row, never the order *within* it, so results are
    /// bit-identical across pool sizes.
    ///
    /// Against the dense kernel the only op-sequence difference is the
    /// elided `0·b` products of `self`'s zero entries — and `x + (±0.0)`
    /// is bitwise `x` because the accumulators start at `+0.0` and can
    /// never become `-0.0`, so for finite inputs the result is byte-equal
    /// to `self.to_dense().matmul_on(other, pool)`
    /// (`csr_matmul_bit_identical_to_dense` pins this).
    pub fn matmul_on(&self, other: &Matrix, pool: &WorkPool) -> Matrix {
        self.matmul_streams(other, pool, pool.threads())
    }

    /// Shared engine: split output rows into `<= max_streams` tasks of at
    /// least [`MM_TASK_FLOPS`] each and run them on `pool` (crate-visible
    /// so the encoder can cap concurrency without a dedicated pool).
    pub(crate) fn matmul_streams(
        &self,
        other: &Matrix,
        pool: &WorkPool,
        max_streams: usize,
    ) -> Matrix {
        assert_eq!(self.cols, other.rows(), "dimension mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols());
        if self.rows == 0 || other.cols() == 0 {
            return out;
        }
        let flops = self.nnz().saturating_mul(other.cols());
        let tasks = (flops / MM_TASK_FLOPS)
            .clamp(1, max_streams.max(1))
            .min(self.rows);
        let rows_per = self.rows.div_ceil(tasks);
        let n = other.cols();
        pool.run_chunks_mut(&mut out.data, rows_per * n, |t, out_rows| {
            for (li, orow) in out_rows.chunks_mut(n).enumerate() {
                let (cols, vals) = self.row_entries(t * rows_per + li);
                spmm_row(cols, vals, other.data(), n, orow);
            }
        });
        out
    }
}

/// One sparse output row: `out_row (1×n) += Σ_nz vals·b[cols]`, with the
/// `n` dimension processed in [`SPMM_NR`]-wide register tiles. Per output
/// element the nonzeros are accumulated in stored (ascending-column)
/// order regardless of the tile width — the tiles partition *columns* of
/// the output, not the reduction — so the result is independent of
/// [`SPMM_NR`] and of how rows were assigned to pool tasks. An empty row
/// writes nothing and leaves the zeroed output untouched.
fn spmm_row(cols: &[usize], vals: &[f64], b: &[f64], n: usize, out_row: &mut [f64]) {
    if cols.is_empty() {
        return;
    }
    for jc in (0..n).step_by(SPMM_NR) {
        let w = SPMM_NR.min(n - jc);
        let mut acc = [0.0f64; SPMM_NR];
        for (&c, &v) in cols.iter().zip(vals) {
            let brow = &b[c * n + jc..c * n + jc + w];
            for (a, &bv) in acc[..w].iter_mut().zip(brow) {
                *a += v * bv;
            }
        }
        out_row[jc..jc + w].copy_from_slice(&acc[..w]);
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// LU factorization with partial pivoting: `P·A = L·U`.
pub struct Lu {
    n: usize,
    /// Combined L (strict lower, unit diagonal implicit) and U (upper).
    lu: Vec<f64>,
    /// Row permutation: `perm[i]` is the original row in position `i`.
    perm: Vec<usize>,
    /// Parity of the permutation (for determinants).
    sign: f64,
}

impl Lu {
    /// Factor a square matrix. Fails on structural singularity.
    pub fn factor(a: &Matrix) -> Result<Lu> {
        if a.rows != a.cols {
            return Err(Error::Numerical(format!(
                "LU requires square matrix, got {}x{}",
                a.rows, a.cols
            )));
        }
        let n = a.rows;
        let mut lu = a.data.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for col in 0..n {
            // Partial pivot: find max |entry| in this column at/below diag.
            let mut piv = col;
            let mut max = lu[col * n + col].abs();
            for r in (col + 1)..n {
                let v = lu[r * n + col].abs();
                if v > max {
                    max = v;
                    piv = r;
                }
            }
            if max == 0.0 || !max.is_finite() {
                return Err(Error::Numerical(format!(
                    "singular matrix at column {col} (pivot {max})"
                )));
            }
            if piv != col {
                for j in 0..n {
                    lu.swap(col * n + j, piv * n + j);
                }
                perm.swap(col, piv);
                sign = -sign;
            }
            let d = lu[col * n + col];
            for r in (col + 1)..n {
                let f = lu[r * n + col] / d;
                lu[r * n + col] = f;
                if f != 0.0 {
                    // Split the row buffer so we can read the pivot row while
                    // updating row r (r > col always holds here).
                    let (top, bottom) = lu.split_at_mut(r * n);
                    let pivot_row = &top[col * n..col * n + n];
                    let row_r = &mut bottom[..n];
                    for j in (col + 1)..n {
                        row_r[j] -= f * pivot_row[j];
                    }
                }
            }
        }
        Ok(Lu { n, lu, perm, sign })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n {
            return Err(Error::Numerical("rhs length mismatch".into()));
        }
        let n = self.n;
        // Apply permutation.
        let mut x: Vec<f64> = (0..n).map(|i| b[self.perm[i]]).collect();
        // Forward substitution (unit lower).
        for i in 1..n {
            let mut acc = x[i];
            for j in 0..i {
                acc -= self.lu[i * n + j] * x[j];
            }
            x[i] = acc;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in (i + 1)..n {
                acc -= self.lu[i * n + j] * x[j];
            }
            x[i] = acc / self.lu[i * n + i];
        }
        Ok(x)
    }

    /// Solve `A·X = B` for a whole matrix of RHS columns in one pass.
    ///
    /// The permutation and both substitution sweeps run row-wise across all
    /// columns at once (row-major friendly), reusing this factorization —
    /// the multi-RHS decode fast path. Per column the operation sequence is
    /// exactly [`Lu::solve`]'s (no terms are skipped, so even NaN/inf inputs
    /// propagate identically), making each result column bit-identical to a
    /// single solve of that column.
    pub fn solve_matrix(&self, b: &Matrix) -> Result<Matrix> {
        if b.rows() != self.n {
            return Err(Error::Numerical(format!(
                "rhs has {} rows, factorization is {}×{}",
                b.rows(),
                self.n,
                self.n
            )));
        }
        let n = self.n;
        let m = b.cols();
        let mut x = Matrix::zeros(n, m);
        // Apply the row permutation.
        for i in 0..n {
            x.data[i * m..(i + 1) * m].copy_from_slice(b.row(self.perm[i]));
        }
        // Forward substitution (unit lower), all columns per row sweep.
        // No zero-multiplier skip: [`Lu::solve`] has none, and skipping
        // would diverge on non-finite inputs (0·NaN ≠ nothing).
        for i in 1..n {
            let (above, below) = x.data.split_at_mut(i * m);
            let row_i = &mut below[..m];
            for j in 0..i {
                let f = self.lu[i * n + j];
                let row_j = &above[j * m..(j + 1) * m];
                for (xi, &xj) in row_i.iter_mut().zip(row_j.iter()) {
                    *xi -= f * xj;
                }
            }
        }
        // Back substitution.
        for i in (0..n).rev() {
            let (above, below) = x.data.split_at_mut((i + 1) * m);
            let row_i = &mut above[i * m..(i + 1) * m];
            for j in (i + 1)..n {
                let f = self.lu[i * n + j];
                let row_j = &below[(j - i - 1) * m..(j - i) * m];
                for (xi, &xj) in row_i.iter_mut().zip(row_j.iter()) {
                    *xi -= f * xj;
                }
            }
            let d = self.lu[i * n + i];
            for xi in row_i.iter_mut() {
                *xi /= d;
            }
        }
        Ok(x)
    }

    /// Multi-RHS solve into a reusable flat scratch buffer: `columns[c]`
    /// is one length-`n` RHS; the permuted system is staged in `scratch`
    /// (`n × columns.len()` row-major — resized once, then reused across
    /// calls with no further allocation) and both substitution sweeps run
    /// in place. Per column the operation sequence is exactly
    /// [`Lu::solve_matrix`]'s (and therefore [`Lu::solve`]'s — keep the
    /// three in sync), so each returned column is bit-identical to a
    /// single solve of that column. This is the allocation-free engine
    /// behind [`crate::coding::Decoder::decode_batch`].
    pub fn solve_columns(
        &self,
        columns: &[Vec<f64>],
        scratch: &mut Vec<f64>,
    ) -> Result<Vec<Vec<f64>>> {
        let n = self.n;
        let m = columns.len();
        for (c, col) in columns.iter().enumerate() {
            if col.len() != n {
                return Err(Error::Numerical(format!(
                    "rhs column {c} has {} rows, factorization is {n}×{n}",
                    col.len()
                )));
            }
        }
        if m == 0 {
            return Ok(Vec::new());
        }
        scratch.clear();
        scratch.resize(n * m, 0.0);
        let x = &mut scratch[..n * m];
        // Stage the row permutation.
        for i in 0..n {
            let p = self.perm[i];
            let row = &mut x[i * m..(i + 1) * m];
            for (xi, col) in row.iter_mut().zip(columns) {
                *xi = col[p];
            }
        }
        // Forward substitution (unit lower), all columns per row sweep.
        for i in 1..n {
            let (above, below) = x.split_at_mut(i * m);
            let row_i = &mut below[..m];
            for j in 0..i {
                let f = self.lu[i * n + j];
                let row_j = &above[j * m..(j + 1) * m];
                for (xi, &xj) in row_i.iter_mut().zip(row_j.iter()) {
                    *xi -= f * xj;
                }
            }
        }
        // Back substitution.
        for i in (0..n).rev() {
            let (above, below) = x.split_at_mut((i + 1) * m);
            let row_i = &mut above[i * m..(i + 1) * m];
            for j in (i + 1)..n {
                let f = self.lu[i * n + j];
                let row_j = &below[(j - i - 1) * m..(j - i) * m];
                for (xi, &xj) in row_i.iter_mut().zip(row_j.iter()) {
                    *xi -= f * xj;
                }
            }
            let d = self.lu[i * n + i];
            for xi in row_i.iter_mut() {
                *xi /= d;
            }
        }
        Ok((0..m)
            .map(|c| (0..n).map(|r| x[r * m + c]).collect())
            .collect())
    }

    /// Determinant from the factorization.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.n {
            d *= self.lu[i * self.n + i];
        }
        d
    }

    /// Cheap conditioning proxy: ratio of max to min |U diagonal|.
    pub fn diag_ratio(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for i in 0..self.n {
            let v = self.lu[i * self.n + i].abs();
            lo = lo.min(v);
            hi = hi.max(v);
        }
        hi / lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Rng;

    #[test]
    fn matvec_and_matmul_small() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let y = a.matvec(&[1.0, 0.0, -1.0]);
        assert_eq!(y, vec![-2.0, -2.0]);
        let b = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(1);
        let a = Matrix::from_fn(5, 5, |_, _| rng.next_f64());
        let i = Matrix::identity(5);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn lu_solves_known_system() {
        // [[2,1],[1,3]] x = [3,5]  =>  x = [4/5, 7/5]
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 3.0]);
        let x = a.solve(&[3.0, 5.0]).unwrap();
        assert!((x[0] - 0.8).abs() < 1e-14);
        assert!((x[1] - 1.4).abs() < 1e-14);
    }

    #[test]
    fn lu_random_roundtrip() {
        let mut rng = Rng::new(2);
        for n in [1usize, 2, 5, 16, 64] {
            let a = Matrix::from_fn(n, n, |_, _| rng.normal());
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let x = a.solve(&b).unwrap();
            for (xs, xt) in x.iter().zip(&x_true) {
                assert!((xs - xt).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    #[allow(deprecated)] // matmul_blocked: the shim must stay bit-correct
    fn blocked_matmul_matches_naive_all_shapes() {
        // Reference kernel: the pre-blocking naive i-k-j loop.
        fn naive(a: &Matrix, b: &Matrix) -> Matrix {
            let mut out = Matrix::zeros(a.rows(), b.cols());
            for i in 0..a.rows() {
                for kk in 0..a.cols() {
                    let av = a[(i, kk)];
                    if av == 0.0 {
                        continue;
                    }
                    for j in 0..b.cols() {
                        out[(i, j)] += av * b[(kk, j)];
                    }
                }
            }
            out
        }
        let mut rng = Rng::new(9);
        // Shapes straddling the tile sizes (64/512), the register-tile
        // height (4), and the task-granularity cutoff.
        for (m, k, n) in [
            (1, 1, 1),
            (3, 70, 5),
            (4, 4, 4),
            (5, 33, 9),
            (65, 64, 513),
            (130, 200, 96),
        ] {
            let a = Matrix::from_fn(m, k, |_, _| rng.normal());
            let b = Matrix::from_fn(k, n, |_, _| rng.normal());
            let want = naive(&a, &b);
            assert_eq!(a.matmul(&b), want, "m={m} k={k} n={n} (global pool)");
            for threads in [1usize, 0, 3] {
                let got = a.matmul_blocked(&b, threads);
                assert_eq!(got, want, "m={m} k={k} n={n} threads={threads}");
            }
            for pool_size in [1usize, 2, 7] {
                let pool = WorkPool::new(pool_size);
                let got = a.matmul_on(&b, &pool);
                assert_eq!(got, want, "m={m} k={k} n={n} pool={pool_size}");
            }
        }
    }

    #[test]
    fn microkernel_bit_identical_to_scalar_fallback() {
        // The register-blocked kernel must be byte-equal to the scalar
        // kernel for finite inputs — including zero-heavy patterns like
        // the systematic identity block, where the two kernels take
        // different zero-skip paths.
        let mut rng = Rng::new(41);
        for (m, k, n) in [(4, 8, 8), (7, 64, 17), (66, 65, 130), (129, 32, 513)] {
            for zero_density in [0.0f64, 0.5, 0.95] {
                let a = Matrix::from_fn(m, k, |i, j| {
                    if rng.next_f64() < zero_density {
                        0.0
                    } else if i == j {
                        1.0 // identity-ish diagonal, systematic style
                    } else {
                        rng.normal()
                    }
                });
                let b = Matrix::from_fn(k, n, |_, _| rng.normal());
                let mut micro = vec![0.0; m * n];
                let mut scalar = vec![0.0; m * n];
                matmul_block_micro(m, k, n, a.data(), b.data(), &mut micro);
                matmul_block(m, k, n, a.data(), b.data(), &mut scalar);
                assert!(
                    micro.iter().zip(&scalar).all(|(x, y)| {
                        x.to_bits() == y.to_bits()
                    }),
                    "m={m} k={k} n={n} zeros={zero_density}"
                );
            }
        }
    }

    #[test]
    fn solve_matrix_matches_column_solves() {
        let mut rng = Rng::new(12);
        for n in [1usize, 4, 17, 64] {
            let a = Matrix::from_fn(n, n, |_, _| rng.normal());
            let b = Matrix::from_fn(n, 5, |_, _| rng.normal());
            let lu = a.lu().unwrap();
            let x = lu.solve_matrix(&b).unwrap();
            assert_eq!(x.rows(), n);
            assert_eq!(x.cols(), 5);
            for c in 0..5 {
                let col: Vec<f64> = (0..n).map(|r| b[(r, c)]).collect();
                let want = lu.solve(&col).unwrap();
                for r in 0..n {
                    assert_eq!(x[(r, c)], want[r], "n={n} col={c} row={r}");
                }
            }
        }
        // Shape mismatch rejected.
        let a = Matrix::identity(3);
        assert!(a.lu().unwrap().solve_matrix(&Matrix::zeros(4, 2)).is_err());
    }

    #[test]
    fn solve_columns_matches_solve_matrix_and_reuses_scratch() {
        let mut rng = Rng::new(13);
        let mut scratch = Vec::new();
        for n in [1usize, 5, 32] {
            let a = Matrix::from_fn(n, n, |_, _| rng.normal());
            let lu = a.lu().unwrap();
            let columns: Vec<Vec<f64>> = (0..6)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            let b = Matrix::from_fn(n, 6, |r, c| columns[c][r]);
            let want = lu.solve_matrix(&b).unwrap();
            let got = lu.solve_columns(&columns, &mut scratch).unwrap();
            for (c, col) in got.iter().enumerate() {
                for (r, v) in col.iter().enumerate() {
                    assert_eq!(
                        v.to_bits(),
                        want[(r, c)].to_bits(),
                        "n={n} col={c} row={r}"
                    );
                }
            }
            // Second call with the sized scratch must not reallocate.
            let cap = scratch.capacity();
            let again = lu.solve_columns(&columns, &mut scratch).unwrap();
            assert_eq!(again, got);
            assert_eq!(scratch.capacity(), cap, "n={n}: scratch grew");
            // Bad column length rejected; empty batch is empty.
            assert!(lu.solve_columns(&[vec![0.0; n + 1]], &mut scratch).is_err());
            assert!(lu.solve_columns(&[], &mut scratch).unwrap().is_empty());
        }
    }

    #[test]
    fn lu_detects_singular() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(a.lu().is_err());
        let z = Matrix::zeros(3, 3);
        assert!(z.lu().is_err());
    }

    #[test]
    fn lu_requires_pivoting() {
        // Zero on the diagonal forces a row swap.
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let x = a.solve(&[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn determinant() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 1.0, 1.0, 2.0]);
        assert!((a.lu().unwrap().det() - 5.0).abs() < 1e-12);
        let i = Matrix::identity(4);
        assert!((i.lu().unwrap().det() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn select_rows_and_transpose() {
        let a = Matrix::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let s = a.select_rows(&[2, 0]);
        assert_eq!(s.data(), &[5.0, 6.0, 1.0, 2.0]);
        let t = a.transpose();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.data(), &[1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(2, 2, vec![1.0, -7.0, 3.0, 2.0]);
        assert_eq!(a.max_abs(), 7.0);
        assert_eq!(a.norm_inf(), 8.0);
    }

    #[test]
    fn push_row_appends_without_disturbing_existing_rows() {
        let mut a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let before: Vec<u64> = a.data().iter().map(|v| v.to_bits()).collect();
        a.push_row(&[7.0, 8.0, 9.0]).unwrap();
        assert_eq!(a.rows(), 3);
        assert_eq!(a.row(2), &[7.0, 8.0, 9.0]);
        assert!(a
            .data()
            .iter()
            .take(before.len())
            .map(|v| v.to_bits())
            .eq(before.iter().copied()));
        assert!(a.push_row(&[1.0]).is_err(), "width mismatch rejected");
    }

    /// Sparse test patterns shared by the CSR unit tests: each returns a
    /// dense matrix whose sparsity shape is adversarial for the kernel's
    /// row partitioning (empty rows, a lone dense row, a single live
    /// column, nothing at all, and a mixed random pattern).
    fn sparse_patterns(rows: usize, cols: usize, seed: u64) -> Vec<(&'static str, Matrix)> {
        let mut rng = Rng::new(seed);
        vec![
            (
                "empty-rows",
                Matrix::from_fn(rows, cols, |i, _| {
                    if i % 3 == 0 {
                        0.0
                    } else {
                        rng.normal()
                    }
                }),
            ),
            (
                "one-dense-row",
                Matrix::from_fn(rows, cols, |i, _| {
                    if i == rows / 2 {
                        rng.normal()
                    } else {
                        0.0
                    }
                }),
            ),
            (
                "single-column",
                Matrix::from_fn(rows, cols, |_, j| {
                    if j == cols / 3 {
                        rng.normal()
                    } else {
                        0.0
                    }
                }),
            ),
            ("all-zero", Matrix::zeros(rows, cols)),
            (
                "random-sparse",
                Matrix::from_fn(rows, cols, |_, _| {
                    if rng.next_f64() < 0.85 {
                        0.0
                    } else {
                        rng.normal()
                    }
                }),
            ),
        ]
    }

    #[test]
    fn csr_dense_roundtrip_and_counts() {
        for (name, a) in sparse_patterns(23, 17, 51) {
            let csr = CsrMatrix::from_dense(&a);
            assert_eq!(csr.rows(), 23, "{name}");
            assert_eq!(csr.cols(), 17, "{name}");
            let expect_nnz = a.data().iter().filter(|&&v| v != 0.0).count();
            assert_eq!(csr.nnz(), expect_nnz, "{name}");
            assert_eq!(csr.to_dense(), a, "{name}");
            // Columns ascend within every row.
            for i in 0..csr.rows() {
                let (cols, vals) = csr.row_entries(i);
                assert_eq!(cols.len(), vals.len(), "{name}");
                assert!(cols.windows(2).all(|w| w[0] < w[1]), "{name} row {i}");
            }
        }
    }

    #[test]
    fn csr_from_parts_validates() {
        // A valid 2×3 matrix: [[0, 1.5, 0], [2.0, 0, -3.0]].
        let ok = CsrMatrix::from_parts(
            2,
            3,
            vec![0, 1, 3],
            vec![1, 0, 2],
            vec![1.5, 2.0, -3.0],
        )
        .unwrap();
        assert_eq!(ok.nnz(), 3);
        assert_eq!(ok.to_dense().row(1), &[2.0, 0.0, -3.0]);
        // indptr arity, span, monotonicity; index order and bounds;
        // value/index length mismatch.
        assert!(CsrMatrix::from_parts(2, 3, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(CsrMatrix::from_parts(1, 3, vec![0, 2], vec![0], vec![1.0]).is_err());
        assert!(CsrMatrix::from_parts(1, 3, vec![1, 1], vec![0], vec![1.0]).is_err());
        assert!(
            CsrMatrix::from_parts(1, 3, vec![0, 2], vec![2, 1], vec![1.0, 1.0]).is_err()
        );
        assert!(CsrMatrix::from_parts(1, 3, vec![0, 1], vec![3], vec![1.0]).is_err());
        assert!(CsrMatrix::from_parts(1, 3, vec![0, 1], vec![0], vec![]).is_err());
    }

    #[test]
    fn csr_matvec_bit_identical_to_dense() {
        let mut rng = Rng::new(52);
        for (name, a) in sparse_patterns(31, 19, 53) {
            let x: Vec<f64> = (0..19).map(|_| rng.normal()).collect();
            let want = a.matvec(&x);
            let got = CsrMatrix::from_dense(&a).matvec(&x);
            assert_eq!(want.len(), got.len(), "{name}");
            assert!(
                want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                "{name}: sparse matvec diverged from dense"
            );
        }
    }

    #[test]
    fn csr_matmul_bit_identical_to_dense() {
        // Shapes straddling the register-tile width (SPMM_NR = 8) and the
        // task-granularity cutoff; every adversarial sparsity pattern.
        for (rows, kdim, n) in [(13, 9, 1), (37, 29, 24), (64, 48, 130)] {
            for (name, a) in sparse_patterns(rows, kdim, 54 + n as u64) {
                let b = Matrix::from_fn(kdim, n, |i, j| {
                    let mut rng = Rng::new((i * n + j) as u64 + 1);
                    rng.normal()
                });
                let want = a.matmul_on(&b, &WorkPool::new(1));
                for pool_size in [1usize, 2, 7] {
                    let pool = WorkPool::new(pool_size);
                    let got = CsrMatrix::from_dense(&a).matmul_on(&b, &pool);
                    assert!(
                        want.data()
                            .iter()
                            .zip(got.data())
                            .all(|(w, g)| w.to_bits() == g.to_bits()),
                        "{name} {rows}x{kdim}x{n} pool={pool_size}"
                    );
                }
            }
        }
    }
}
