//! The rateless random-linear fountain code (`rateless-rlc`) — the
//! registry's fourth entry, and the first whose generator is an
//! **infinite row stream**.
//!
//! # Why rateless
//!
//! The paper's MDS construction fixes `n` at encode time, so adaptation
//! can only re-slice the rows that exist: growing the fleet past `n` or
//! riding out per-packet loss costs a full re-encode. A random-linear
//! fountain removes the ceiling — row `i ∈ [0, ∞)` is `k` Gaussians
//! scaled by `1/√k`, derived purely from `(seed, i)`
//! ([`GeneratorKind::RatelessRlc`]), and the master decodes the moment it
//! holds *any* invertible `k`-set. Workers simply stream rows until that
//! threshold; fresh workers get fresh row ranges with zero re-encode work
//! (measured by [`crate::coding::Encoder::re_encoded_rows`], not
//! declared).
//!
//! # Determinism argument
//!
//! Every coefficient row is a pure function of `(seed, i)` through
//! `math::rng` — there is no shared stream cursor, so materializing the
//! prefix in one shot, extending it incrementally, or deriving a row on
//! demand inside [`crate::coding::Generator::submatrix`] all read the
//! same bits. That is what makes the serving results reproducible from
//! the seed at any pool size, any extension schedule, and any packet
//! arrival order (the collection loop sorts receipts deterministically;
//! see `coordinator::rateless`).
//!
//! # Decode
//!
//! Decode is unchanged: the received global row indices select a `k×k`
//! system that goes through the cached-LU any-k path
//! ([`crate::coding::Decoder::decode_batch`]). A random Gaussian `k`-set
//! is invertible with probability 1, so unlike `sparse-parity` there is
//! no structural singularity class — but the decoder still surfaces a
//! numerically singular set as a clean `Err` instead of garbage.

use crate::coding::code::Code;
use crate::coding::GeneratorKind;

/// The rateless random-linear fountain code. Non-systematic; any-k
/// decode through the shared cached-LU path; the only registry entry
/// whose `n` can grow after setup ([`crate::coding::Encoder::extend_to`]
/// + [`Code::encode_rows`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct RatelessCode;

impl Code for RatelessCode {
    fn name(&self) -> &'static str {
        "rateless-rlc"
    }

    fn generator(&self) -> GeneratorKind {
        GeneratorKind::RatelessRlc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{Decoder, Encoder, Matrix};
    use crate::math::Rng;
    use crate::runtime::pool::WorkPool;

    #[test]
    fn streamed_rows_decode_from_any_k_receipt_set() {
        let code = RatelessCode;
        let (n, k, d) = (6usize, 4usize, 3usize);
        let mut rng = Rng::new(31);
        let a = Matrix::from_fn(k, d, |_, _| rng.normal());
        let gen = code.setup(n, k, 8).unwrap();
        let encoder = Encoder::new(gen.clone());
        let pool = WorkPool::new(2);
        // Stream far past the setup prefix, as the serving loop would
        // under loss: rows [0, 6) at setup, [6, 12) minted later.
        let head = code.encode_rows(&encoder, &a, 0..n, &pool, 2).unwrap();
        let tail = code.encode_rows(&encoder, &a, n..2 * n, &pool, 2).unwrap();
        assert_eq!(encoder.re_encoded_rows(), 0);
        let x: Vec<f64> = (0..d).map(|i| 0.5 - i as f64).collect();
        let truth = a.matvec(&x);
        let y_head = head.matvec(&x);
        let y_tail = tail.matvec(&x);
        // A receipt set straddling the extension boundary decodes.
        let rows = [1usize, 4, 7, 11];
        let col: Vec<f64> = rows
            .iter()
            .map(|&r| if r < n { y_head[r] } else { y_tail[r - n] })
            .collect();
        let mut decoder = Decoder::new(gen);
        let decoded = code.decode_rows(&mut decoder, &rows, &[col]).unwrap();
        for (got, want) in decoded[0].iter().zip(&truth) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        // Sub-k receipt sets fail fast and clean.
        assert!(code
            .decode_rows(&mut decoder, &rows[..3], &[vec![0.0; 3]])
            .is_err());
    }
}
