//! Shared plumbing for the figure-regeneration benches.
//!
//! Each `figN_*` bench regenerates its paper figure through the library's
//! figure harness (same code the CLI uses), prints the data table + ASCII
//! plot, writes the CSV under `results/`, and times the generation with the
//! in-repo bench harness. `cargo bench` therefore reproduces every table
//! and figure in the paper's evaluation in one command.

use hetcoded::figures::{generate, Figure, FigureOpts};

/// Samples per MC point used by benches: smaller than the paper's 1e4 so a
/// full `cargo bench` stays tractable, overridable via HETCODED_BENCH_SAMPLES.
pub fn bench_opts() -> FigureOpts {
    let samples = std::env::var("HETCODED_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4_000);
    let points = std::env::var("HETCODED_BENCH_POINTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    FigureOpts { samples, points, seed: 2019, threads: 0 }
}

/// Regenerate figure `n`, print it, persist the CSV, and report timing.
pub fn run_figure_bench(n: u8) {
    let opts = bench_opts();
    hetcoded::bench::section(&format!(
        "figure {n} (samples={}, points={})",
        opts.samples, opts.points
    ));
    let t0 = hetcoded::runtime::wall_now();
    let fig: Figure = generate(n, &opts).expect("figure generation failed");
    let elapsed = t0.elapsed();
    println!("{}", fig.ascii_plot());
    print_table(&fig);
    let path = fig
        .write_csv(std::path::Path::new("results"))
        .expect("write csv");
    println!(
        "generated in {} -> {}",
        hetcoded::bench::fmt_time(elapsed.as_secs_f64()),
        path.display()
    );
}

/// Print the numeric series table (the "rows the paper reports").
pub fn print_table(fig: &Figure) {
    for s in &fig.series {
        println!("series: {}", s.name);
        for &(x, y) in &s.points {
            println!("  {x:>14.6e}  {y:>14.6e}");
        }
    }
}
