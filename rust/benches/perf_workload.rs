//! Benchmarks over the workload layer's hot paths:
//!
//! - arrival-trace generation (Poisson and bursty ON/OFF);
//! - single-job service sampling (the Rényi any-`k` merge, per draw);
//! - a full throughput-under-load run (arrivals → FIFO queue → metrics)
//!   at serving scale for the two headline policies;
//! - the sharded admission front end (tenant-keyed shard queues,
//!   work-stealing drain, SLO-adaptive batching) at 100k–200k arrivals,
//!   plus a live front-end `Session` serve through the coordinator.

use hetcoded::allocation::{policy, uniform_allocation};
use hetcoded::bench::{black_box, run, run_quick, section};
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{
    FrontEndConfig, JobConfig, Mode, NativeCompute, Session,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, EstimatorConfig, Group, LatencyModel};
use hetcoded::sim::Scheme;
use hetcoded::workload::{
    mean_service, run_admission, run_workload, run_workload_drift,
    service_sampler, AdaptPolicy, AdmissionConfig, ArrivalProcess,
    BatchPolicy, DriftEvent, DriftKind, DriftSchedule, DriftWorkloadConfig,
    SloConfig, TenantSpec, WorkloadConfig,
};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    section("arrival generation (10k jobs per call)");
    run("poisson", || {
        let mut rng = Rng::new(7);
        let ts = ArrivalProcess::Poisson { rate: 5.0 }
            .times(10_000, &mut rng)
            .unwrap();
        black_box(ts.len());
    });
    run("onoff (bursty)", || {
        let mut rng = Rng::new(7);
        let ts = ArrivalProcess::OnOff {
            rate_on: 10.0,
            mean_on: 2.0,
            mean_off: 2.0,
        }
        .times(10_000, &mut rng)
        .unwrap();
        black_box(ts.len());
    });

    let spec = ClusterSpec::paper_two_group(10_000);

    section("service sampling (1k draws per call, 2-group N=900 cluster)");
    for (name, scheme) in [
        ("proposed", Scheme::Proposed),
        ("uniform-n*", Scheme::UniformWithOptimalN),
        ("group-code r=100", Scheme::GroupCode(100.0)),
    ] {
        let sampler = match service_sampler(&spec, scheme, LatencyModel::A) {
            Ok((_, s)) => s,
            Err(e) => {
                println!("  {name}: skipped ({e})");
                continue;
            }
        };
        run(name, || {
            let mut s = sampler.clone();
            let mut rng = Rng::new(13);
            let mut acc = 0.0;
            for _ in 0..1_000 {
                acc += s.sample(&mut rng);
            }
            black_box(acc);
        });
    }

    section("full workload run (2k jobs, rho ~ 0.8)");
    for (name, scheme) in [
        ("proposed", Scheme::Proposed),
        ("uniform-n*", Scheme::UniformWithOptimalN),
    ] {
        let (_, mut sampler) =
            service_sampler(&spec, scheme, LatencyModel::A).unwrap();
        let es = hetcoded::workload::mean_service(&mut sampler, 1_000, 3);
        let cfg = WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 0.8 / es },
            jobs: 2_000,
            servers: 1,
            seed: 2019,
        };
        run_quick(&format!("workload {name}"), || {
            let rep =
                run_workload(&spec, scheme, LatencyModel::A, &cfg).unwrap();
            black_box(rep.throughput);
        });
    }

    section("admission front end (sharded, multi-tenant, event-driven)");
    {
        let p = policy::resolve("proposed").unwrap();
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es = mean_service(&mut sampler, 1_000, 3);
        run_quick("admission 100k fifo-parity (1 shard, 1 tenant)", || {
            let cfg = AdmissionConfig::fifo_parity(
                ArrivalProcess::Poisson { rate: 0.8 / es },
                100_000,
                1,
                2019,
            );
            let rep =
                run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();
            black_box(rep.throughput);
        });
        // 8 tenants at 0.45/E[S] each over 4 drainers: rho = 0.9 per
        // drainer at single-job batches — the saturation knee batching
        // is meant to push past.
        let sharded = |batch| AdmissionConfig {
            tenants: (0..8)
                .map(|_| TenantSpec {
                    arrivals: ArrivalProcess::Poisson { rate: 0.45 / es },
                    weight: 1.0,
                })
                .collect(),
            jobs: 200_000,
            shards: 4,
            drainers: 4,
            steal: true,
            batch,
            amortize: 0.75,
            seed: 2019,
        };
        run_quick("admission 200k 4-shard steal fixed-batch", || {
            let rep = run_admission(
                &spec,
                &*p,
                LatencyModel::A,
                &sharded(BatchPolicy::Fixed(16)),
            )
            .unwrap();
            black_box((rep.throughput, rep.steals));
        });
        run_quick("admission 200k 4-shard steal slo-adaptive", || {
            let rep = run_admission(
                &spec,
                &*p,
                LatencyModel::A,
                &sharded(BatchPolicy::Adaptive(SloConfig {
                    target_p99: 25.0 * es,
                    ..Default::default()
                })),
            )
            .unwrap();
            black_box((rep.throughput, rep.final_batch_limit));
        });
    }

    section("live front end (Session drain, coordinator + WorkPool)");
    {
        let spec = ClusterSpec::new(
            vec![
                Group { n: 4, mu: 8.0, alpha: 1.0 },
                Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(11);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let reqs: Vec<Vec<f64>> = (0..64)
            .map(|_| (0..8).map(|_| rng.normal()).collect())
            .collect();
        let offsets = vec![Duration::ZERO; 64];
        run_quick("live front-end serve 64 req (2 shards x 4 tenants)", || {
            let outcome = Session::builder(&spec)
                .allocation(alloc.clone())
                .data(a.clone())
                .requests(reqs.clone())
                .config(JobConfig {
                    time_scale: 0.002,
                    seed: 7,
                    ..Default::default()
                })
                .compute(Arc::new(NativeCompute))
                .front_end(FrontEndConfig {
                    shards: 2,
                    tenants: 4,
                    weights: Vec::new(),
                    batch: None,
                })
                .mode(Mode::Arrivals {
                    offsets: offsets.clone(),
                    max_batch: 8,
                })
                .build()
                .unwrap()
                .serve()
                .unwrap();
            black_box(outcome.front_end.unwrap().batches);
        });
    }

    section("drift experiment (3-group N=24, 3k jobs, mid-stream 2x slowdown)");
    {
        let spec = ClusterSpec::new(
            vec![
                Group { n: 6, mu: 8.0, alpha: 1.0 },
                Group { n: 8, mu: 4.0, alpha: 1.0 },
                Group { n: 10, mu: 1.0, alpha: 1.0 },
            ],
            1_000,
        )
        .unwrap();
        let schedule = DriftSchedule::new(vec![DriftEvent {
            at: 3_000.0 / (2.0 * 8.2),
            kind: DriftKind::SlowGroup { group: 0, factor: 2.0 },
        }])
        .unwrap();
        let cfg = DriftWorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 8.2 },
            jobs: 3_000,
            seed: 2019,
        };
        run_quick("workload drift static", || {
            let rep = run_workload_drift(
                &spec,
                LatencyModel::A,
                &cfg,
                &schedule,
                &AdaptPolicy::Static,
            )
            .unwrap();
            black_box(rep.sojourn.mean());
        });
        run_quick("workload drift adaptive (estimator + re-solve)", || {
            let rep = run_workload_drift(
                &spec,
                LatencyModel::A,
                &cfg,
                &schedule,
                &AdaptPolicy::Adaptive(EstimatorConfig::default()),
            )
            .unwrap();
            black_box(rep.sojourn.mean());
        });
    }
}
