//! Benchmarks over the workload layer's hot paths:
//!
//! - arrival-trace generation (Poisson and bursty ON/OFF);
//! - single-job service sampling (the Rényi any-`k` merge, per draw);
//! - a full throughput-under-load run (arrivals → FIFO queue → metrics)
//!   at serving scale for the two headline policies.

use hetcoded::bench::{black_box, run, run_quick, section};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, EstimatorConfig, Group, LatencyModel};
use hetcoded::sim::Scheme;
use hetcoded::workload::{
    run_workload, run_workload_drift, service_sampler, AdaptPolicy,
    ArrivalProcess, DriftEvent, DriftKind, DriftSchedule,
    DriftWorkloadConfig, WorkloadConfig,
};

fn main() {
    section("arrival generation (10k jobs per call)");
    run("poisson", || {
        let mut rng = Rng::new(7);
        let ts = ArrivalProcess::Poisson { rate: 5.0 }
            .times(10_000, &mut rng)
            .unwrap();
        black_box(ts.len());
    });
    run("onoff (bursty)", || {
        let mut rng = Rng::new(7);
        let ts = ArrivalProcess::OnOff {
            rate_on: 10.0,
            mean_on: 2.0,
            mean_off: 2.0,
        }
        .times(10_000, &mut rng)
        .unwrap();
        black_box(ts.len());
    });

    let spec = ClusterSpec::paper_two_group(10_000);

    section("service sampling (1k draws per call, 2-group N=900 cluster)");
    for (name, scheme) in [
        ("proposed", Scheme::Proposed),
        ("uniform-n*", Scheme::UniformWithOptimalN),
        ("group-code r=100", Scheme::GroupCode(100.0)),
    ] {
        let sampler = match service_sampler(&spec, scheme, LatencyModel::A) {
            Ok((_, s)) => s,
            Err(e) => {
                println!("  {name}: skipped ({e})");
                continue;
            }
        };
        run(name, || {
            let mut s = sampler.clone();
            let mut rng = Rng::new(13);
            let mut acc = 0.0;
            for _ in 0..1_000 {
                acc += s.sample(&mut rng);
            }
            black_box(acc);
        });
    }

    section("full workload run (2k jobs, rho ~ 0.8)");
    for (name, scheme) in [
        ("proposed", Scheme::Proposed),
        ("uniform-n*", Scheme::UniformWithOptimalN),
    ] {
        let (_, mut sampler) =
            service_sampler(&spec, scheme, LatencyModel::A).unwrap();
        let es = hetcoded::workload::mean_service(&mut sampler, 1_000, 3);
        let cfg = WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 0.8 / es },
            jobs: 2_000,
            servers: 1,
            seed: 2019,
        };
        run_quick(&format!("workload {name}"), || {
            let rep =
                run_workload(&spec, scheme, LatencyModel::A, &cfg).unwrap();
            black_box(rep.throughput);
        });
    }

    section("drift experiment (3-group N=24, 3k jobs, mid-stream 2x slowdown)");
    {
        let spec = ClusterSpec::new(
            vec![
                Group { n: 6, mu: 8.0, alpha: 1.0 },
                Group { n: 8, mu: 4.0, alpha: 1.0 },
                Group { n: 10, mu: 1.0, alpha: 1.0 },
            ],
            1_000,
        )
        .unwrap();
        let schedule = DriftSchedule::new(vec![DriftEvent {
            at: 3_000.0 / (2.0 * 8.2),
            kind: DriftKind::SlowGroup { group: 0, factor: 2.0 },
        }])
        .unwrap();
        let cfg = DriftWorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 8.2 },
            jobs: 3_000,
            seed: 2019,
        };
        run_quick("workload drift static", || {
            let rep = run_workload_drift(
                &spec,
                LatencyModel::A,
                &cfg,
                &schedule,
                &AdaptPolicy::Static,
            )
            .unwrap();
            black_box(rep.sojourn.mean());
        });
        run_quick("workload drift adaptive (estimator + re-solve)", || {
            let rep = run_workload_drift(
                &spec,
                LatencyModel::A,
                &cfg,
                &schedule,
                &AdaptPolicy::Adaptive(EstimatorConfig::default()),
            )
            .unwrap();
            black_box(rep.sojourn.mean());
        });
    }
}
