//! Bench: regenerate the paper's uniform fixed-rate sweep vs q (Fig 7).
mod common;

fn main() {
    common::run_figure_bench(7);
}
