//! Bench: regenerate the paper's latency vs q at N=2500 (Fig 5).
mod common;

fn main() {
    common::run_figure_bench(5);
}
