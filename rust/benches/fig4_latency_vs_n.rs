//! Bench: regenerate the paper's latency vs N, five groups (Fig 4).
mod common;

fn main() {
    common::run_figure_bench(4);
}
