//! Bench: regenerate the paper's model-B proposed vs [32] (Fig 9).
mod common;

fn main() {
    common::run_figure_bench(9);
}
