//! Micro-benchmarks over the hot paths the §Perf pass optimizes:
//!
//! - Lambert W evaluation (allocation inner loop);
//! - proposed allocation end-to-end;
//! - Monte-Carlo latency sampling (`latency_any_k` / `latency_per_group`);
//! - LU factorization + decode at serving sizes;
//! - factorization-cached vs uncached decode on a repeated straggler
//!   pattern, and batched multi-RHS vs per-request decode (single and
//!   pooled);
//! - MDS encode (setup path) on the persistent pool, and the spawn-vs-pool
//!   dispatch overhead the PR 5 runtime removed;
//! - small-matrix matmul latency (the granularity gate must keep it at
//!   single-stream speed — the old flat spawn threshold's failure mode);
//! - end-to-end `run_job` through the thread coordinator (native backend);
//! - prepared-job vs cold batched serving (the encode-hoisting fast path,
//!   now allocation-free and pool-backed in steady state);
//! - sparse-vs-dense encode ablation: the CSR O(nnz·d) kernel behind the
//!   `sparse-parity` code against the dense register-blocked kernel on
//!   the same generator matrix, single-stream and pooled;
//! - the rateless fountain: fresh-range `encode_rows` extension (the
//!   streaming loop's mint pattern) and streamed serving on clean vs
//!   10%-lossy links;
//! - the recovery layer: hedged serving with no failures (the deadline
//!   bookkeeping tax over plain prepared serving), hedged serving
//!   through a stalled group (blown deadlines re-issued as MDS spare
//!   rows), and the per-batch deadline staging pass itself.
//!
//! Set `BENCH_JSON_DIR` (or run `make bench-json`) to capture `name →
//! ns/op` into the current PR's `BENCH_PR<N>.json`.

use hetcoded::allocation::proposed_allocation;
use hetcoded::bench::{black_box, run, run_quick, section};
use hetcoded::coding::{Decoder, Encoder, Generator, GeneratorKind, Matrix};
use hetcoded::coordinator::{
    JobConfig, Mode, NativeCompute, PreparedJob, RecoveryConfig,
    RecoveryEngine, Session, StragglerInjector,
};
use hetcoded::math::{wm1_neg_exp, Rng};
use hetcoded::model::{ClusterSpec, LatencyModel};
use hetcoded::runtime::pool::WorkPool;
use hetcoded::sim::{latency_any_k, latency_per_group, SimConfig};
use std::sync::Arc;

fn main() {
    section("runtime: pool dispatch vs per-call thread spawn");
    // The overhead PR 5 removes from every parallel hot-path call: a
    // `std::thread::scope` pays 8 OS spawns + joins per call, the
    // persistent pool one channel push per helper + an atomic claim per
    // task. This gap is what the >=2x serving/sweep headline comes from
    // at small per-batch work sizes.
    // The spawn here is the measured baseline itself, not a shortcut
    // around the pool — the one bench where raw thread creation is the
    // point.
    #[allow(clippy::disallowed_methods)]
    run("spawn 8 scoped threads (noop, per-call baseline)", || {
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {});
            }
        });
    });
    let pool8 = WorkPool::new(8);
    run("pool dispatch 8 tasks (noop, persistent workers)", || {
        pool8.scope_run(8, |_| {});
    });

    section("math");
    run("lambertw: wm1_neg_exp over t in [1, 750]", || {
        let mut acc = 0.0;
        for i in 0..1_000 {
            acc += wm1_neg_exp(1.0 + i as f64 * 0.749);
        }
        black_box(acc);
    });

    section("allocation");
    let spec = ClusterSpec::paper_five_group(2500, 10_000);
    run("proposed_allocation (G=5, N=2500)", || {
        black_box(proposed_allocation(LatencyModel::A, &spec).unwrap());
    });

    section("monte-carlo");
    let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
    let cfg = SimConfig { samples: 1_000, seed: 7, threads: 1 };
    run_quick("latency_any_k: N=2500, 1k samples, 1 thread", || {
        black_box(latency_any_k(&spec, &alloc.loads, LatencyModel::A, &cfg).unwrap());
    });
    let cfg_mt = SimConfig { samples: 1_000, seed: 7, threads: 0 };
    run_quick("latency_any_k: N=2500, 1k samples, auto threads", || {
        black_box(latency_any_k(&spec, &alloc.loads, LatencyModel::A, &cfg_mt).unwrap());
    });
    // The fig4-9 sweep shape at the headline thread count: one MC point
    // exactly as the figure harness dispatches it, 8 deterministic streams
    // on the persistent pool (pre-PR 5 this spawned 8 threads per point).
    let cfg_8 = SimConfig { samples: 1_000, seed: 7, threads: 8 };
    run_quick("latency_any_k: N=2500, 1k samples, 8 streams (fig sweep point)", || {
        black_box(latency_any_k(&spec, &alloc.loads, LatencyModel::A, &cfg_8).unwrap());
    });
    let r = vec![20.0, 20.0, 20.0, 20.0, 20.0];
    run_quick("latency_per_group: N=2500, 1k samples", || {
        black_box(
            latency_per_group(&spec, &alloc.loads, &r, LatencyModel::A, &cfg).unwrap(),
        );
    });

    section("coding");
    let mut rng = Rng::new(3);
    for k in [128usize, 256] {
        let n = k * 3 / 2;
        let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 1).unwrap();
        let sub_rows: Vec<usize> = (n - k..n).collect();
        let sub = gen.submatrix(&sub_rows);
        let b: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        run(&format!("LU solve k={k} (decode hot path)"), || {
            let lu = sub.lu().unwrap();
            black_box(lu.solve(&b).unwrap());
        });
        let a = Matrix::from_fn(k, 64, |_, _| rng.normal());
        run_quick(&format!("encode G({n}x{k}) @ A({k}x64)"), || {
            black_box(gen.matrix().matmul(&a));
        });
    }

    section("decode at serving sizes: cached vs uncached, batched vs per-request");
    for k in [256usize, 1024] {
        let n = k * 3 / 2;
        let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 1).unwrap();
        // Repeated straggler pattern: the all-parity support (worst case
        // for conditioning, and the kind of fixed pattern group-boundary
        // straggling produces batch after batch).
        let received: Vec<(usize, f64)> =
            (n - k..n).map(|i| (i, rng.normal())).collect();
        let mut cold = Decoder::with_cache_capacity(gen.clone(), 0);
        run_quick(&format!("decode k={k} uncached (refactor per call)"), || {
            black_box(cold.decode(&received).unwrap());
        });
        let mut warm = Decoder::new(gen.clone());
        warm.decode(&received).unwrap(); // populate the factorization cache
        run_quick(&format!("decode k={k} cached (repeated pattern)"), || {
            black_box(warm.decode(&received).unwrap());
        });
        let rows: Vec<usize> = (n - k..n).collect();
        let cols: Vec<Vec<f64>> =
            (0..32).map(|_| (0..k).map(|_| rng.normal()).collect()).collect();
        let mut dec = Decoder::new(gen.clone());
        dec.decode_batch(&rows, &cols).unwrap(); // warm cache for both
        run_quick(&format!("decode k={k} B=32 multi-RHS (one pass)"), || {
            black_box(dec.decode_batch(&rows, &cols).unwrap());
        });
        let mut dec_pooled = Decoder::new(gen.clone());
        dec_pooled.set_pool(Some(Arc::new(WorkPool::new(8))));
        dec_pooled.decode_batch(&rows, &cols).unwrap(); // warm cache + arenas
        run_quick(&format!("decode k={k} B=32 multi-RHS (pooled, 8 workers)"), || {
            black_box(dec_pooled.decode_batch(&rows, &cols).unwrap());
        });
        run_quick(&format!("decode k={k} B=32 per-request loop"), || {
            for col in &cols {
                let pairs: Vec<(usize, f64)> =
                    rows.iter().copied().zip(col.iter().copied()).collect();
                black_box(dec.decode(&pairs).unwrap());
            }
        });
    }

    section("blocked matmul (encode kernel at serving sizes)");
    {
        let (k, n, d) = (1024usize, 1536usize, 256usize);
        let gen =
            Generator::new(GeneratorKind::SystematicRandom, n, k, 1).unwrap();
        let a = Matrix::from_fn(k, d, |_, _| rng.normal());
        let pool1 = WorkPool::new(1);
        // Names kept from the pre-pool snapshots ("1 thread"/"auto
        // threads") for cross-PR diffability; both now run the
        // register-blocked microkernel, inline vs on the persistent pool.
        run_quick(&format!("encode G({n}x{k}) @ A({k}x{d}), 1 thread"), || {
            black_box(gen.matrix().matmul_on(&a, &pool1));
        });
        run_quick(&format!("encode G({n}x{k}) @ A({k}x{d}), auto threads"), || {
            black_box(gen.matrix().matmul(&a));
        });
        run_quick(&format!("encode G({n}x{k}) @ A({k}x{d}), pool of 8"), || {
            black_box(gen.matrix().matmul_on(&a, &pool8));
        });
    }

    section("sparse vs dense encode (CSR kernel ablation, same generator)");
    {
        // The sparse-parity generator at the serving size above: 1024
        // systematic singletons + 512 weight-8 parity rows (~0.33% dense),
        // encoded through the CSR kernel vs the dense register-blocked
        // kernel on the *same* matrix. The ratio is the O(nnz·d) claim.
        let (k, n, d) = (1024usize, 1536usize, 256usize);
        let gen = Generator::new(GeneratorKind::SparseParity, n, k, 1).unwrap();
        let csr = gen.sparse().expect("sparse-parity generator carries CSR");
        let a = Matrix::from_fn(k, d, |_, _| rng.normal());
        let enc = Encoder::new(gen.clone());
        let pool1 = WorkPool::new(1);
        run_quick(&format!("sparse encode G({n}x{k}) w=8 @ A({k}x{d}), pool of 8"), || {
            black_box(enc.encode_capped(&a, &pool8, 8).unwrap());
        });
        run_quick(&format!("sparse csr matmul G({n}x{k}) @ A({k}x{d}), 1 thread"), || {
            black_box(csr.matmul_on(&a, &pool1));
        });
        run_quick(&format!("sparse csr matmul G({n}x{k}) @ A({k}x{d}), pool of 8"), || {
            black_box(csr.matmul_on(&a, &pool8));
        });
        run_quick(&format!("dense matmul same sparse G({n}x{k}) @ A({k}x{d}), 1 thread"), || {
            black_box(gen.matrix().matmul_on(&a, &pool1));
        });
        run_quick(&format!("dense matmul same sparse G({n}x{k}) @ A({k}x{d}), pool of 8"), || {
            black_box(gen.matrix().matmul_on(&a, &pool8));
        });
    }

    section("small-matrix matmul (granularity gate: no pooling regression)");
    {
        // Below one task grain the pooled path must collapse to the
        // inline kernel: identical latency with a 1-worker and an
        // 8-worker pool. (The old flat 1 MFLOP spawn threshold got this
        // right only by never threading anything medium-sized.)
        let pool1 = WorkPool::new(1);
        let a32 = Matrix::from_fn(32, 32, |_, _| rng.normal());
        let b32 = Matrix::from_fn(32, 32, |_, _| rng.normal());
        run("matmul 32x32x32 single-stream", || {
            black_box(a32.matmul_on(&b32, &pool1));
        });
        run("matmul 32x32x32 pooled (gated inline)", || {
            black_box(a32.matmul_on(&b32, &pool8));
        });
        let a128 = Matrix::from_fn(128, 128, |_, _| rng.normal());
        let b128 = Matrix::from_fn(128, 128, |_, _| rng.normal());
        run("matmul 128x128x128 single-stream", || {
            black_box(a128.matmul_on(&b128, &pool1));
        });
        run("matmul 128x128x128 pooled (granularity-split)", || {
            black_box(a128.matmul_on(&b128, &pool8));
        });
    }

    section("coordinator end-to-end (native backend)");
    let live_spec = ClusterSpec::new(
        vec![
            hetcoded::model::Group { n: 6, mu: 8.0, alpha: 1.0 },
            hetcoded::model::Group { n: 8, mu: 4.0, alpha: 1.0 },
            hetcoded::model::Group { n: 10, mu: 1.0, alpha: 1.0 },
        ],
        256,
    )
    .unwrap();
    let live_alloc = proposed_allocation(LatencyModel::A, &live_spec).unwrap();
    let a = Matrix::from_fn(256, 256, |_, _| rng.normal());
    let x: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
    let jcfg = JobConfig { time_scale: 0.001, ..Default::default() };
    // Benched through a pre-built Session so the measured loop is the cold
    // engine itself (the deprecated shims clone the matrix/requests per
    // call, which would skew ns/op vs earlier snapshots; the bench names
    // stay unchanged for cross-PR comparability).
    let single_session = Session::builder(&live_spec)
        .allocation(live_alloc.clone())
        .data(a.clone())
        .requests(vec![x.clone()])
        .config(jcfg.clone())
        .mode(Mode::Single)
        .build()
        .unwrap();
    run_quick("run_job: N=24 workers, k=256, d=256", || {
        black_box(single_session.serve().unwrap());
    });

    section("prepared vs cold batched serving (k=256, d=256, B=8)");
    let requests: Vec<Vec<f64>> =
        (0..8).map(|_| (0..256).map(|_| rng.normal()).collect()).collect();
    let batched_session = Session::builder(&live_spec)
        .allocation(live_alloc.clone())
        .data(a.clone())
        .requests(requests.clone())
        .config(jcfg.clone())
        .mode(Mode::Batched)
        .build()
        .unwrap();
    run_quick("serve batch cold (re-encode per batch)", || {
        black_box(batched_session.serve().unwrap());
    });
    let mut prepared =
        PreparedJob::new(&live_spec, &live_alloc, &a, &jcfg).unwrap();
    let mut batch_seed = 0u64;
    run_quick("serve batch prepared (steady state)", || {
        batch_seed += 1;
        black_box(
            prepared
                .run_batch(&requests, Arc::new(NativeCompute), batch_seed)
                .unwrap(),
        );
    });
    // Production shape: skip the O(k·d)-per-request ground-truth matvec.
    let noverify = JobConfig { verify_decode: false, ..jcfg.clone() };
    let mut prepared_nv =
        PreparedJob::new(&live_spec, &live_alloc, &a, &noverify).unwrap();
    run_quick("serve batch prepared (no verify)", || {
        batch_seed += 1;
        black_box(
            prepared_nv
                .run_batch(&requests, Arc::new(NativeCompute), batch_seed)
                .unwrap(),
        );
    });

    section("rateless fountain: extension encode and streamed serving");
    // The fountain's extra cost vs a fixed-n code: per-range row
    // derivation (seeded Gaussians, no cached generator prefix) and the
    // streamed round loop. Packet-fate draws are the per-packet overhead
    // the lossy path pays on every reply.
    {
        use hetcoded::coding::code;
        let rl = code::resolve("rateless-rlc").unwrap();
        let (n, k, d) = (384usize, 256usize, 64usize);
        let ra = Matrix::from_fn(k, d, |_, _| rng.normal());
        let gen = rl.setup(n, k, 21).unwrap();
        let encoder = Encoder::new(gen);
        let pool = WorkPool::new(8);
        let mut at = 0usize;
        run("rateless encode_rows 384-row extension (k=256, d=64)", || {
            // Fresh ranges forever: the monotone mint pattern of the
            // streaming loop, never a re-encode.
            let got = rl
                .encode_rows(&encoder, &ra, at..at + n, &pool, 8)
                .unwrap();
            at += n;
            black_box(got);
        });
        let rl_cfg = JobConfig {
            time_scale: 0.001,
            code: Some("rateless-rlc".into()),
            verify_decode: false,
            ..Default::default()
        };
        let mut rl_prepared =
            PreparedJob::new(&live_spec, &live_alloc, &a, &rl_cfg).unwrap();
        run_quick("serve batch streamed rateless (clean links)", || {
            batch_seed += 1;
            black_box(
                rl_prepared
                    .run_batch_streamed(
                        &requests,
                        Arc::new(NativeCompute),
                        batch_seed,
                        &[],
                    )
                    .unwrap(),
            );
        });
        let loss = vec![0.1f64; live_spec.total_workers()];
        run_quick("serve batch streamed rateless (10% packet loss)", || {
            batch_seed += 1;
            black_box(
                rl_prepared
                    .run_batch_streamed(
                        &requests,
                        Arc::new(NativeCompute),
                        batch_seed,
                        &loss,
                    )
                    .unwrap(),
            );
        });
    }

    section("recovery: hedged serving and deadline staging");
    // The hedging tax when nothing fails (deadline staging + per-reply
    // bookkeeping over plain prepared serving), and the stalled-group
    // shape where blown deadlines actually fire re-issues: every hedge
    // is an MDS spare row the executor computes fresh — never a
    // re-encode. The bench mirrors the serving loop's per-batch
    // sequence: stage deadlines, run hedged, finish_batch.
    {
        let nw = live_spec.total_workers();
        let mut hedged =
            PreparedJob::new(&live_spec, &live_alloc, &a, &jcfg).unwrap();
        let injector = StragglerInjector::sample(
            &live_spec,
            LatencyModel::A,
            hedged.per_worker(),
            jcfg.time_scale,
            33,
        )
        .unwrap();
        let mut engine =
            RecoveryEngine::new(RecoveryConfig::default(), nw).unwrap();
        let clean = vec![false; nw];
        run_quick("serve batch hedged (no failures)", || {
            batch_seed += 1;
            engine
                .stage(LatencyModel::A, &live_spec, hedged.per_worker())
                .unwrap();
            let (reports, _obs, degraded) = hedged
                .run_batch_hedged(
                    &requests,
                    Arc::new(NativeCompute),
                    &injector,
                    &[],
                    batch_seed,
                    &clean,
                    &mut engine,
                )
                .unwrap();
            assert!(degraded.is_none());
            engine.finish_batch();
            black_box(reports);
        });
        // Stall the fast group (workers 0..6): short deadlines blow
        // quickly, their rows re-dispatch to idle survivors, and after
        // `quarantine_after` iterations the steady state is the
        // quarantine ring's canary-plus-cover-hedge path.
        let mut stalled = vec![false; nw];
        for s in stalled.iter_mut().take(6) {
            *s = true;
        }
        let mut engine_stall =
            RecoveryEngine::new(RecoveryConfig::default(), nw).unwrap();
        run_quick("serve batch hedged (stalled group, mds spare rows)", || {
            batch_seed += 1;
            engine_stall
                .stage(LatencyModel::A, &live_spec, hedged.per_worker())
                .unwrap();
            let (reports, _obs, degraded) = hedged
                .run_batch_hedged(
                    &requests,
                    Arc::new(NativeCompute),
                    &injector,
                    &[],
                    batch_seed,
                    &stalled,
                    &mut engine_stall,
                )
                .unwrap();
            assert!(degraded.is_none());
            engine_stall.finish_batch();
            black_box(reports);
        });
        // The analytic staging pass alone: one quantile evaluation per
        // worker per batch — the fixed cost every hedged batch pays
        // before any work is dispatched.
        let spec10 = ClusterSpec::new(
            vec![
                hetcoded::model::Group { n: 4, mu: 8.0, alpha: 1.0 },
                hetcoded::model::Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap();
        let loads10 = vec![12usize; 10];
        let mut eng10 =
            RecoveryEngine::new(RecoveryConfig::default(), 10).unwrap();
        run("recovery stage deadlines (10 workers)", || {
            eng10.stage(LatencyModel::A, &spec10, &loads10).unwrap();
            black_box(eng10.deadline_model(9));
        });
    }
}
