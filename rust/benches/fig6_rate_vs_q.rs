//! Bench: regenerate the paper's rate k/n* vs q (Fig 6).
mod common;

fn main() {
    common::run_figure_bench(6);
}
