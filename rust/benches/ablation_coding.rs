//! Ablation bench: MDS generator construction (DESIGN.md design choice).
//!
//! Compares the two generator families on (a) decode numerical error and
//! (b) decode wall time, as the code dimension `k` grows. Demonstrates why
//! `SystematicRandom` is the default: Chebyshev-Vandermonde decoding is
//! exact-MDS but its conditioning collapses past k ≈ 24, while the random
//! construction stays at f64 roundoff for practical k.

use hetcoded::bench::{black_box, run_quick, section};
use hetcoded::coding::{decoder::roundtrip_check, Generator, GeneratorKind, Matrix};
use hetcoded::math::Rng;

fn decode_error(kind: GeneratorKind, k: usize, seed: u64) -> f64 {
    let n = k * 2;
    let gen = Generator::new(kind, n, k, seed).unwrap();
    let mut rng = Rng::new(seed ^ 0xABCD);
    let a = Matrix::from_fn(k, 4, |_, _| rng.normal());
    let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
    // Worst case: all-parity decode.
    let rows: Vec<usize> = (n - k..n).collect();
    roundtrip_check(&gen, &a, &x, &rows).unwrap_or(f64::INFINITY)
}

fn main() {
    section("ablation: decode error vs k (all-parity rows, rate 1/2)");
    println!(
        "{:>6} {:>24} {:>24}",
        "k", "vandermonde max|err|", "systematic-random max|err|"
    );
    for k in [4usize, 8, 12, 16, 20, 24, 32, 64, 128, 256] {
        let v = decode_error(GeneratorKind::Vandermonde, k, 1);
        let s = decode_error(GeneratorKind::SystematicRandom, k, 1);
        println!("{k:>6} {v:>24.3e} {s:>24.3e}");
    }

    section("ablation: decode time vs k (systematic-random)");
    for k in [64usize, 128, 256, 512] {
        let n = k * 3 / 2;
        let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 2).unwrap();
        let rows: Vec<usize> = (n - k..n).collect();
        let sub = gen.submatrix(&rows);
        let mut rng = Rng::new(3);
        let b: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        run_quick(&format!("LU factor+solve k={k}"), || {
            let lu = sub.lu().unwrap();
            black_box(lu.solve(&b).unwrap());
        });
    }
}
