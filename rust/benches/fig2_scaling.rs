//! Bench: regenerate the paper's N*T* scaling vs q (Fig 2).
mod common;

fn main() {
    common::run_figure_bench(2);
}
