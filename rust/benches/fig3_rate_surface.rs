//! Bench: regenerate the paper's rate k/n* over (N2,mu2) (Fig 3).
mod common;

fn main() {
    common::run_figure_bench(3);
}
