//! Bench: regenerate the paper's latency vs rate, two groups (Fig 8).
mod common;

fn main() {
    common::run_figure_bench(8);
}
