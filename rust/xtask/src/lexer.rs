//! Minimal Rust lexer for the invariant rule engine.
//!
//! Emits identifier and punctuation tokens with 1-based line numbers and
//! records which lines carry a safety comment (`// SAFETY:` or a
//! `/// # Safety` doc section). Comments, strings (including raw and
//! byte strings), char literals, lifetimes, and numeric literals are
//! consumed and dropped: the rules only pattern-match identifiers and
//! structure, so a token the rules cannot name must not be able to hide
//! one they can (a `partial_cmp` inside a string or comment is not a
//! finding; one split across lines by rustfmt is).

/// One significant token.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    /// 1-based source line the token starts on.
    pub line: usize,
    pub kind: TokenKind,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TokenKind {
    Ident(String),
    Punct(char),
}

impl Token {
    /// The identifier text, if this token is an identifier.
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokenKind::Ident(s) => Some(s.as_str()),
            TokenKind::Punct(_) => None,
        }
    }

    /// True when this token is exactly the punctuation `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokenKind::Punct(c)
    }
}

/// Lexer output: the token stream plus comment metadata.
pub struct Lexed {
    pub tokens: Vec<Token>,
    /// Lines (1-based) on which a comment mentioning a safety contract
    /// starts or continues (used by rule S1).
    pub safety_lines: Vec<usize>,
}

fn is_safety_comment(text: &str) -> bool {
    text.contains("SAFETY") || text.contains("# Safety")
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens. Never fails: unterminated constructs consume
/// to end of input (the linter runs on code the compiler may not have
/// seen yet; it must degrade, not abort).
pub fn lex(src: &str) -> Lexed {
    Lexer {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
        tokens: Vec::new(),
        safety_lines: Vec::new(),
    }
    .run()
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: usize,
    tokens: Vec<Token>,
    safety_lines: Vec<usize>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) {
        if self.peek(0) == Some('\n') {
            self.line += 1;
        }
        self.i += 1;
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if c == '"' {
                self.bump();
                self.string_body();
            } else if c == '\'' {
                self.quote();
            } else if (c == 'r' || c == 'b') && self.string_prefix() {
                // consumed by string_prefix
            } else if is_ident_start(c) {
                self.ident();
            } else if c.is_ascii_digit() {
                self.number();
            } else {
                self.tokens.push(Token {
                    line: self.line,
                    kind: TokenKind::Punct(c),
                });
                self.bump();
            }
        }
        Lexed { tokens: self.tokens, safety_lines: self.safety_lines }
    }

    fn line_comment(&mut self) {
        let start = self.i;
        let line = self.line;
        while self.peek(0).is_some_and(|c| c != '\n') {
            self.bump();
        }
        let text: String = self.chars[start..self.i].iter().collect();
        if is_safety_comment(&text) {
            self.safety_lines.push(line);
        }
    }

    fn block_comment(&mut self) {
        let start = self.i;
        let start_line = self.line;
        let mut depth = 0usize;
        while self.peek(0).is_some() {
            if self.peek(0) == Some('/') && self.peek(1) == Some('*') {
                depth += 1;
                self.bump();
                self.bump();
            } else if self.peek(0) == Some('*') && self.peek(1) == Some('/') {
                self.bump();
                self.bump();
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else {
                self.bump();
            }
        }
        let text: String =
            self.chars[start..self.i.min(self.chars.len())].iter().collect();
        if is_safety_comment(&text) {
            for l in start_line..=self.line {
                self.safety_lines.push(l);
            }
        }
    }

    /// Body of a `"…"` string; the opening quote is already consumed.
    fn string_body(&mut self) {
        while let Some(c) = self.peek(0) {
            if c == '\\' {
                self.bump();
                self.bump(); // escaped char (line counted by bump)
            } else if c == '"' {
                self.bump();
                return;
            } else {
                self.bump();
            }
        }
    }

    /// Raw string with `hashes` number of `#`s; positioned just past the
    /// opening quote.
    fn raw_string_body(&mut self, hashes: usize) {
        while self.peek(0).is_some() {
            if self.peek(0) == Some('"') {
                let closed =
                    (1..=hashes).all(|k| self.peek(k) == Some('#'));
                self.bump();
                if closed {
                    for _ in 0..hashes {
                        self.bump();
                    }
                    return;
                }
            } else {
                self.bump();
            }
        }
    }

    /// Try to consume an `r"…"`, `r#"…"#`, `b"…"`, `br"…"`, or `b'…'`
    /// prefix starting at the current `r`/`b`. Returns false (consuming
    /// nothing) when this is an ordinary identifier or raw identifier.
    fn string_prefix(&mut self) -> bool {
        let c = self.peek(0).unwrap_or(' ');
        let mut j = 1usize;
        let mut raw = c == 'r';
        if c == 'b' && self.peek(1) == Some('r') {
            raw = true;
            j = 2;
        }
        if c == 'b' && self.peek(1) == Some('\'') {
            // byte char literal b'x'
            self.bump(); // b
            self.quote();
            return true;
        }
        if raw {
            let mut hashes = 0usize;
            while self.peek(j) == Some('#') {
                hashes += 1;
                j += 1;
            }
            if self.peek(j) == Some('"') {
                for _ in 0..=j {
                    self.bump(); // prefix + opening quote
                }
                self.raw_string_body(hashes);
                return true;
            }
            if c == 'r' && hashes == 1 && self.peek(j).is_some_and(is_ident_start)
            {
                // raw identifier r#ident: drop the prefix, lex the name
                self.bump();
                self.bump();
                self.ident();
                return true;
            }
            return false;
        }
        if self.peek(j) == Some('"') {
            for _ in 0..=j {
                self.bump();
            }
            self.string_body();
            return true;
        }
        false
    }

    /// A `'`: lifetime, loop label, or char literal.
    fn quote(&mut self) {
        match self.peek(1) {
            Some('\\') => {
                // '\x' / '\u{..}' / '\'' — consume quote, backslash and
                // the escaped char, then scan to the closing quote.
                self.bump();
                self.bump();
                self.bump();
                while self.peek(0).is_some_and(|c| c != '\'') {
                    self.bump();
                }
                self.bump();
            }
            Some(c2) => {
                if self.peek(2) == Some('\'') {
                    // 'x'
                    self.bump();
                    self.bump();
                    self.bump();
                } else if is_ident_continue(c2) {
                    // lifetime or loop label: 'a, 'static, 'outer
                    self.bump();
                    while self.peek(0).is_some_and(is_ident_continue) {
                        self.bump();
                    }
                } else {
                    // odd char literal (e.g. multi-byte): scan to quote
                    self.bump();
                    self.bump();
                    while self.peek(0).is_some_and(|c| c != '\'') {
                        self.bump();
                    }
                    self.bump();
                }
            }
            None => self.bump(),
        }
    }

    fn ident(&mut self) {
        let line = self.line;
        let start = self.i;
        while self.peek(0).is_some_and(is_ident_continue) {
            self.bump();
        }
        let text: String = self.chars[start..self.i].iter().collect();
        self.tokens.push(Token { line, kind: TokenKind::Ident(text) });
    }

    /// Numeric literal: digits/alnum run, one fractional part. Exponent
    /// signs (`1e-3`) fall out as separate punctuation — harmless, no
    /// rule matches numbers. The `0..n` range form is preserved because
    /// `.` is only folded in when followed by a digit.
    fn number(&mut self) {
        while self.peek(0).is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
        {
            self.bump();
        }
        if self.peek(0) == Some('.')
            && self.peek(1).is_some_and(|c| c.is_ascii_digit())
        {
            self.bump();
            while self
                .peek(0)
                .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
            {
                self.bump();
            }
        }
    }
}

/// Mark which tokens belong to test-only items: any item annotated with
/// an attribute containing the bare identifier `test` (`#[test]`,
/// `#[cfg(test)]`, `#[cfg(all(test, …))]`) and every token through the
/// end of that item (its brace-matched body or terminating semicolon).
/// Attributes containing `not` are conservatively treated as non-test —
/// `#[cfg(not(test))]` code is production code.
pub fn test_mask(tokens: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if !tokens[i].is_punct('#') {
            i += 1;
            continue;
        }
        // Inner attribute #![…]: structural, never a test marker.
        if tokens.get(i + 1).is_some_and(|t| t.is_punct('!')) {
            if let Some(close) = match_delim(tokens, i + 2, '[', ']') {
                i = close + 1;
                continue;
            }
        }
        if !tokens.get(i + 1).is_some_and(|t| t.is_punct('[')) {
            i += 1;
            continue;
        }
        let Some(close) = match_delim(tokens, i + 1, '[', ']') else {
            break;
        };
        let attr = &tokens[i + 2..close];
        let has = |name: &str| attr.iter().any(|t| t.ident() == Some(name));
        if !has("test") || has("not") {
            i = close + 1;
            continue;
        }
        // Test item: consume any further attributes, then skip to the
        // end of the item (first top-level `{`…`}` or `;`).
        let mut k = close + 1;
        while tokens.get(k).is_some_and(|t| t.is_punct('#'))
            && tokens.get(k + 1).is_some_and(|t| t.is_punct('['))
        {
            match match_delim(tokens, k + 1, '[', ']') {
                Some(c) => k = c + 1,
                None => break,
            }
        }
        let mut paren = 0i64;
        let mut bracket = 0i64;
        let mut end = tokens.len() - 1;
        while k < tokens.len() {
            let t = &tokens[k];
            if t.is_punct('(') {
                paren += 1;
            } else if t.is_punct(')') {
                paren -= 1;
            } else if t.is_punct('[') {
                bracket += 1;
            } else if t.is_punct(']') {
                bracket -= 1;
            } else if t.is_punct('{') && paren == 0 && bracket == 0 {
                end = match_delim(tokens, k, '{', '}')
                    .unwrap_or(tokens.len() - 1);
                break;
            } else if t.is_punct(';') && paren == 0 && bracket == 0 {
                end = k;
                break;
            }
            k += 1;
        }
        for m in i..=end {
            mask[m] = true;
        }
        i = end + 1;
    }
    mask
}

/// Index of the delimiter matching `open` at `start` (which must hold
/// the opening delimiter), or None when unbalanced.
fn match_delim(
    tokens: &[Token],
    start: usize,
    open: char,
    close: char,
) -> Option<usize> {
    if !tokens.get(start).is_some_and(|t| t.is_punct(open)) {
        return None;
    }
    let mut depth = 0i64;
    for (j, t) in tokens.iter().enumerate().skip(start) {
        if t.is_punct(open) {
            depth += 1;
        } else if t.is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.ident().map(|s| s.to_string()))
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_identifiers() {
        let src = r##"
            // partial_cmp in a comment
            /* nested /* partial_cmp */ still comment */
            let s = "partial_cmp";
            let r = r#"partial_cmp "quoted" inside"#;
            let real = a.total_cmp(&b);
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|s| s == "partial_cmp"));
        assert!(ids.iter().any(|s| s == "total_cmp"));
    }

    #[test]
    fn lifetimes_and_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = 'x'; \
                   let q = '\\''; let n = '\\n'; loop { break; } c }";
        let ids = idents(src);
        assert!(ids.contains(&"loop".to_string()));
        // The quote handling must not swallow the `break` keyword.
        assert!(ids.contains(&"break".to_string()));
    }

    #[test]
    fn safety_comment_lines_recorded() {
        let src = "fn f() {\n    // SAFETY: fine\n    g();\n}\n";
        let lexed = lex(src);
        assert_eq!(lexed.safety_lines, vec![2]);
    }

    #[test]
    fn line_numbers_survive_multiline_strings() {
        let src = "let a = \"two\nlines\";\nlet marker = 1;\n";
        let lexed = lex(src);
        let marker = lexed
            .tokens
            .iter()
            .find(|t| t.ident() == Some("marker"))
            .expect("marker token");
        assert_eq!(marker.line, 3);
    }

    #[test]
    fn test_mask_covers_cfg_test_mod() {
        let src = "fn live() { x.unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { y.unwrap(); }\n}\n";
        let lexed = lex(src);
        let mask = test_mask(&lexed.tokens);
        let unwraps: Vec<bool> = lexed
            .tokens
            .iter()
            .zip(&mask)
            .filter(|(t, _)| t.ident() == Some("unwrap"))
            .map(|(_, m)| *m)
            .collect();
        assert_eq!(unwraps, vec![false, true]);
    }

    #[test]
    fn cfg_not_test_is_not_masked() {
        let src = "#[cfg(not(test))]\nfn live() { x.unwrap(); }\n";
        let lexed = lex(src);
        let mask = test_mask(&lexed.tokens);
        assert!(mask.iter().all(|m| !m));
    }
}
