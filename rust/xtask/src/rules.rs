//! The invariant rules (D1–D5, S1–S2).
//!
//! Each rule is a token-pattern over the lexed stream of one file,
//! scoped by the file's repo-relative path. Rules that guard *runtime*
//! determinism (D2, S2) exempt test code — tests may unwrap and may
//! iterate hash maps because their output never feeds decoded bytes;
//! rules that guard *source* hygiene (D1, D3, D4, D5, S1) apply
//! everywhere, tests included, so a pattern can't incubate in a test
//! and get copy-pasted into a hot path.

use crate::lexer::{lex, test_mask, Token};

/// One rule finding.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Rule identifier: "D1".."D5", "S1", "S2".
    pub rule: &'static str,
    /// Repo-relative path of the offending file.
    pub path: String,
    /// 1-based line of the triggering token.
    pub line: usize,
    /// Short token-level snippet around the trigger (used for
    /// allowlist `contains` matching and for display).
    pub snippet: String,
    /// Human explanation of what the rule protects.
    pub message: String,
}

/// Directories whose iteration order feeds decoded bytes or scheduling
/// decisions (rule D2).
const D2_DIRS: [&str; 4] = ["coordinator/", "workload/", "sim/", "coding/"];

/// The single module allowed to own threads and unsafe code.
const POOL: &str = "runtime/pool.rs";

/// Run every rule over one file. `relpath` uses `/` separators and is
/// relative to the lint root (e.g. `coordinator/master.rs`).
pub fn check_file(relpath: &str, src: &str) -> Vec<Violation> {
    let lexed = lex(src);
    let tokens = &lexed.tokens;
    let mask = test_mask(tokens);
    let mut out = Vec::new();

    let snippet = |i: usize| -> String {
        let lo = i.saturating_sub(4);
        let hi = (i + 5).min(tokens.len());
        let mut s = String::new();
        for t in &tokens[lo..hi] {
            match &t.kind {
                crate::lexer::TokenKind::Ident(id) => {
                    if !s.is_empty() {
                        s.push(' ');
                    }
                    s.push_str(id);
                }
                crate::lexer::TokenKind::Punct(c) => s.push(*c),
            }
        }
        s
    };

    let in_d2_dir = D2_DIRS.iter().any(|d| relpath.starts_with(d));
    let is_pool = relpath == POOL || relpath.ends_with(&format!("/{POOL}"));
    let in_sim_or_model =
        relpath.starts_with("sim/") || relpath.starts_with("model/");
    let in_runtime =
        relpath.starts_with("runtime/") || relpath.contains("/runtime/");
    let in_math = relpath.starts_with("math/");

    for (i, tok) in tokens.iter().enumerate() {
        let Some(id) = tok.ident() else { continue };
        let next_is =
            |k: usize, c: char| tokens.get(i + k).is_some_and(|t| t.is_punct(c));
        let next_ident = |k: usize| tokens.get(i + k).and_then(|t| t.ident());

        // D1 — float comparator hygiene: any partial_cmp is banned in
        // favor of total_cmp. The method only exists to be combined
        // with unwrap/unwrap_or in comparator closures, and every such
        // combination either panics on NaN or silently reorders.
        if id == "partial_cmp" {
            out.push(Violation {
                rule: "D1",
                path: relpath.to_string(),
                line: tok.line,
                snippet: snippet(i),
                message: "float comparison via partial_cmp — use \
                          f64::total_cmp (NaN-total, panic-free, and the \
                          ordering the bit-identity suites pin)"
                    .to_string(),
            });
        }

        // D2 — no hash containers in order-sensitive trees. Iteration
        // order of HashMap/HashSet is seeded per-process; any use in
        // coordinator/workload/sim/coding risks order-dependent bytes.
        if in_d2_dir
            && !mask[i]
            && (id == "HashMap" || id == "HashSet")
        {
            out.push(Violation {
                rule: "D2",
                path: relpath.to_string(),
                line: tok.line,
                snippet: snippet(i),
                message: format!(
                    "{id} in an order-sensitive tree — iteration order is \
                     per-process random; use BTreeMap/BTreeSet or a sorted \
                     Vec so decoded bytes and schedules stay deterministic"
                ),
            });
        }

        // D3 — thread creation only in runtime/pool.rs. Everything
        // else borrows the persistent WorkPool; ad-hoc spawns reintroduce
        // the per-call spawn cost PR 5 removed and escape the pool's
        // deterministic reduction.
        if !is_pool
            && id == "thread"
            && next_is(1, ':')
            && next_is(2, ':')
            && matches!(next_ident(3), Some("spawn" | "scope" | "Builder"))
        {
            out.push(Violation {
                rule: "D3",
                path: relpath.to_string(),
                line: tok.line,
                snippet: snippet(i),
                message: "thread creation outside runtime/pool.rs — \
                          route the work through the shared WorkPool"
                    .to_string(),
            });
        }

        // D4 — virtual time only in sim/ and model/. A wall-clock read
        // in the simulator or the latency model makes runs
        // irreproducible; `wall_now` (the sanctioned runtime wrapper)
        // is equally banned here.
        if in_sim_or_model
            && matches!(id, "Instant" | "SystemTime" | "wall_now")
        {
            out.push(Violation {
                rule: "D4",
                path: relpath.to_string(),
                line: tok.line,
                snippet: snippet(i),
                message: format!(
                    "{id} in sim/model code — these trees run on virtual \
                     time; wall-clock reads make runs irreproducible"
                ),
            });
        }

        // D4 (call form) — outside sim/model, the clock may be *carried*
        // (`Instant` as a field or signature type is fine) but only
        // runtime/ may *read* it: a direct `Instant::now()` /
        // `SystemTime::now()` call anywhere else — hedge-deadline math
        // being the motivating offender — bypasses `runtime::wall_now`,
        // the single audited read site the recovery determinism
        // arguments lean on. (Scoped out of sim/model to avoid
        // double-reporting: the clause above already bans the bare
        // ident there.)
        if !in_sim_or_model
            && !in_runtime
            && matches!(id, "Instant" | "SystemTime")
            && next_is(1, ':')
            && next_is(2, ':')
            && next_ident(3) == Some("now")
        {
            out.push(Violation {
                rule: "D4",
                path: relpath.to_string(),
                line: tok.line,
                snippet: snippet(i),
                message: format!(
                    "direct {id}::now() read outside runtime/ — take \
                     timestamps and deadlines from runtime::wall_now() so \
                     every wall-clock read stays at one auditable site"
                ),
            });
        }

        // D5 — RNG construction only via math/rng seed derivation.
        // Ambient-entropy constructors break replay; direct struct
        // construction of Rng outside math/ bypasses the stream-seed
        // discipline.
        if matches!(
            id,
            "RandomState" | "DefaultHasher" | "thread_rng" | "from_entropy"
        ) {
            out.push(Violation {
                rule: "D5",
                path: relpath.to_string(),
                line: tok.line,
                snippet: snippet(i),
                message: format!(
                    "{id} draws ambient entropy — all randomness must flow \
                     from math/rng seed-derivation helpers"
                ),
            });
        }
        if !in_math
            && id == "Rng"
            && next_is(1, '{')
            && next_ident(2) == Some("s")
            && next_is(3, ':')
        {
            out.push(Violation {
                rule: "D5",
                path: relpath.to_string(),
                line: tok.line,
                snippet: snippet(i),
                message: "direct Rng struct construction outside math/ — \
                          use Rng::new / Rng::split so stream seeds stay \
                          derived, not invented"
                    .to_string(),
            });
        }

        // S1 — unsafe confined to runtime/pool.rs, and there each
        // occurrence must sit within a few lines of a SAFETY comment
        // stating the invariant it relies on.
        if id == "unsafe" {
            if !is_pool {
                out.push(Violation {
                    rule: "S1",
                    path: relpath.to_string(),
                    line: tok.line,
                    snippet: snippet(i),
                    message: "unsafe outside runtime/pool.rs — the pool is \
                              the only module allowed to carry unsafe code"
                        .to_string(),
                });
            } else {
                let annotated = lexed.safety_lines.iter().any(|&l| {
                    l <= tok.line && tok.line - l <= 8
                });
                if !annotated {
                    out.push(Violation {
                        rule: "S1",
                        path: relpath.to_string(),
                        line: tok.line,
                        snippet: snippet(i),
                        message: "unsafe without a nearby SAFETY comment — \
                                  state the invariant this block relies on \
                                  within the 8 lines above it"
                            .to_string(),
                    });
                }
            }
        }

        // S2 — no unwrap/expect/panic in non-test library code outside
        // the allowlist. Every allowed site must carry a justification
        // in lint_allow.toml.
        if !mask[i] {
            let is_call_unwrap = matches!(id, "unwrap" | "expect")
                && i > 0
                && tokens[i - 1].is_punct('.')
                && next_is(1, '(');
            let is_panic_macro = matches!(
                id,
                "panic" | "unreachable" | "todo" | "unimplemented"
            ) && next_is(1, '!');
            if is_call_unwrap || is_panic_macro {
                out.push(Violation {
                    rule: "S2",
                    path: relpath.to_string(),
                    line: tok.line,
                    snippet: snippet(i),
                    message: format!(
                        "{id} in non-test library code — return a Result, \
                         or allowlist this site with a justification for \
                         why it cannot fire"
                    ),
                });
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(relpath: &str, src: &str) -> Vec<&'static str> {
        let mut r: Vec<&'static str> =
            check_file(relpath, src).into_iter().map(|v| v.rule).collect();
        r.dedup();
        r
    }

    #[test]
    fn d1_fires_anywhere() {
        let src = "fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
        assert_eq!(rules_hit("math/stats.rs", src), vec!["D1"]);
    }

    #[test]
    fn d2_scoped_to_order_sensitive_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_hit("coordinator/master.rs", src), vec!["D2"]);
        assert!(rules_hit("figures/fig7.rs", src).is_empty());
    }

    #[test]
    fn d2_exempts_tests() {
        let src = "#[cfg(test)]\nmod tests { use std::collections::HashMap; }";
        assert!(rules_hit("coding/decoder.rs", src).is_empty());
    }

    #[test]
    fn d3_spots_spawn_scope_builder() {
        for call in ["spawn", "scope", "Builder::new"] {
            let src = format!("fn f() {{ std::thread::{call}(|| ()); }}");
            assert_eq!(
                rules_hit("coordinator/master.rs", &src),
                vec!["D3"],
                "{call}"
            );
        }
        let src = "fn f() { std::thread::spawn(|| ()); }";
        assert!(rules_hit("runtime/pool.rs", src).is_empty());
    }

    #[test]
    fn d4_bans_wall_clock_in_sim_and_model() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(rules_hit("sim/queue.rs", src), vec!["D4"]);
        assert_eq!(rules_hit("model/latency.rs", src), vec!["D4"]);
        let src2 = "fn f() { let t = wall_now(); }";
        assert_eq!(rules_hit("sim/queue.rs", src2), vec!["D4"]);
    }

    #[test]
    fn d4_bans_direct_clock_reads_outside_runtime() {
        // The call is the read: `Instant::now()` / `SystemTime::now()`
        // trip everywhere but runtime/ (home of the wall_now wrapper).
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(rules_hit("coordinator/recovery.rs", src), vec!["D4"]);
        let sys = "fn f() { let t = SystemTime::now(); }";
        assert_eq!(rules_hit("coordinator/metrics.rs", sys), vec!["D4"]);
        assert!(rules_hit("runtime/clock.rs", src).is_empty());
        // `Instant` as a plain type (fields, signatures, elapsed math on
        // a stored stamp) stays legal outside sim/model, and wall_now()
        // is the sanctioned read.
        let typed =
            "pub struct T { at: Instant }\nfn f(t: &T) -> Instant { t.at }";
        assert!(rules_hit("coordinator/metrics.rs", typed).is_empty());
        let sanctioned = "fn f() { let t = wall_now(); }";
        assert!(rules_hit("coordinator/prepared.rs", sanctioned).is_empty());
    }

    #[test]
    fn d5_bans_ambient_entropy_and_raw_construction() {
        let src = "fn f() { let h = RandomState::new(); }";
        assert_eq!(rules_hit("model/latency.rs", src), vec!["D5"]);
        let src2 = "fn f(seed: u64) -> Rng { Rng { s: seed } }";
        assert_eq!(rules_hit("workload/arrivals.rs", src2), vec!["D5"]);
        // math/rng itself constructs the struct — that is the helper.
        assert!(rules_hit("math/rng.rs", src2).is_empty());
    }

    #[test]
    fn s1_unsafe_needs_location_and_annotation() {
        let src = "fn f(p: *const u8) -> u8 { unsafe { *p } }";
        assert_eq!(rules_hit("coding/encoder.rs", src), vec!["S1"]);
        assert_eq!(rules_hit("runtime/pool.rs", src), vec!["S1"]);
        let annotated =
            "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller passes a \
             valid pointer\n    unsafe { *p }\n}";
        assert!(rules_hit("runtime/pool.rs", annotated).is_empty());
        assert_eq!(rules_hit("coding/encoder.rs", annotated), vec!["S1"]);
    }

    #[test]
    fn s2_spots_unwrap_expect_and_panics_outside_tests() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }";
        assert_eq!(rules_hit("workload/queue.rs", src), vec!["S2"]);
        let src2 = "fn f() { panic!(\"boom\"); }";
        assert_eq!(rules_hit("workload/queue.rs", src2), vec!["S2"]);
        let test_src = "#[test]\nfn t() { Some(1).unwrap(); }";
        assert!(rules_hit("workload/queue.rs", test_src).is_empty());
    }

    #[test]
    fn s2_ignores_non_call_idents() {
        // An fn named `expect_len` or a struct field `unwrap` must not trip.
        let src = "fn expect_len() -> usize { 3 }\nstruct S { unwrap: u8 }";
        assert!(rules_hit("workload/queue.rs", src).is_empty());
    }
}
