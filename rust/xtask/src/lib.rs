//! Repo invariant linter (`cargo xtask lint`).
//!
//! Walks a tree of `.rs` files, runs the rule engine from
//! [`rules`] over each, and partitions findings by the allowlist. The
//! binary in `main.rs` is a thin CLI over [`lint_tree`]; the fixture
//! integration tests call it directly.

pub mod allowlist;
pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use allowlist::Allowlist;
use rules::Violation;

/// Result of linting one tree.
pub struct LintOutcome {
    /// Findings not covered by any allowlist entry — these fail the run.
    pub violations: Vec<Violation>,
    /// Findings covered by an allowlist entry, paired with the entry's
    /// justification (reported, never fatal).
    pub suppressed: Vec<(Violation, String)>,
    /// Allowlist entries that matched nothing, as `(toml_line, rule,
    /// path)` — stale entries are a sign the code moved on and the
    /// exemption should be retired.
    pub unused_entries: Vec<(usize, String, String)>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

/// Lint every `.rs` file under `root` (recursively, sorted traversal
/// so output order is deterministic) against `allow`.
pub fn lint_tree(root: &Path, allow: &Allowlist) -> Result<LintOutcome, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)
        .map_err(|e| format!("walking {}: {e}", root.display()))?;
    files.sort();

    let mut violations = Vec::new();
    let mut suppressed = Vec::new();
    let mut hits = vec![0usize; allow.entries.len()];

    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        for v in rules::check_file(&rel, &src) {
            match allow
                .entries
                .iter()
                .enumerate()
                .find(|(_, e)| e.matches(&v))
            {
                Some((idx, entry)) => {
                    hits[idx] += 1;
                    suppressed.push((v, entry.justification.clone()));
                }
                None => violations.push(v),
            }
        }
    }

    let unused_entries = allow
        .entries
        .iter()
        .zip(&hits)
        .filter(|(_, &h)| h == 0)
        .map(|(e, _)| (e.toml_line, e.rule.clone(), e.path.clone()))
        .collect();

    Ok(LintOutcome {
        violations,
        suppressed,
        unused_entries,
        files: files.len(),
    })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
