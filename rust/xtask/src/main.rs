//! `cargo xtask lint` — enforce the repo's determinism/safety
//! invariants (rules D1–D5, S1–S2; see DESIGN.md "Static analysis &
//! enforced invariants").
//!
//! Usage:
//!   cargo xtask lint [--root DIR] [--allowlist FILE]
//!
//! Defaults lint `rust/src` against `rust/xtask/lint_allow.toml`.
//! Exit code 1 on any non-allowlisted violation or a malformed
//! allowlist; 0 otherwise (unused allowlist entries warn but do not
//! fail — the fixture suite asserts the repo run has none).

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::allowlist::Allowlist;
use xtask::lint_tree;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let Some(cmd) = args.next() else {
        eprintln!("usage: cargo xtask lint [--root DIR] [--allowlist FILE]");
        return ExitCode::FAILURE;
    };
    if cmd != "lint" {
        eprintln!("unknown xtask command `{cmd}` (supported: lint)");
        return ExitCode::FAILURE;
    }

    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut root = manifest.join("../src");
    let mut allow_path = manifest.join("lint_allow.toml");
    while let Some(flag) = args.next() {
        match flag.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => {
                    eprintln!("--root requires a directory argument");
                    return ExitCode::FAILURE;
                }
            },
            "--allowlist" => match args.next() {
                Some(v) => allow_path = PathBuf::from(v),
                None => {
                    eprintln!("--allowlist requires a file argument");
                    return ExitCode::FAILURE;
                }
            },
            other => {
                eprintln!("unknown flag `{other}`");
                return ExitCode::FAILURE;
            }
        }
    }

    let allow = match Allowlist::load(&allow_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: malformed allowlist: {e}");
            return ExitCode::FAILURE;
        }
    };

    let outcome = match lint_tree(&root, &allow) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };

    for v in &outcome.violations {
        eprintln!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.message);
        eprintln!("    near: {}", v.snippet);
    }
    for (line, rule, path) in &outcome.unused_entries {
        eprintln!(
            "warning: unused allowlist entry at lint_allow.toml:{line} \
             ({rule} {path}) — retire it"
        );
    }

    eprintln!(
        "xtask lint: {} files, {} violation(s), {} suppressed by \
         allowlist, {} unused allowlist entr(y/ies)",
        outcome.files,
        outcome.violations.len(),
        outcome.suppressed.len(),
        outcome.unused_entries.len(),
    );

    if outcome.violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
