//! The lint allowlist: `xtask/lint_allow.toml`.
//!
//! Format — an array of `[[allow]]` tables, each with:
//!
//! ```toml
//! [[allow]]
//! rule = "S2"                    # required: D1..D5, S1, S2
//! path = "runtime/pool.rs"       # required: repo-relative or suffix
//! contains = "expect"            # optional: snippet substring filter
//! justification = "lock poison is unrecoverable; aborting is correct"
//! ```
//!
//! `justification` is mandatory and must be a real sentence (≥ 15
//! chars) — an allowlist entry is a reviewed decision, not an escape
//! hatch. Parsed with a hand-rolled TOML subset (same no-dependency
//! constraint as the lexer); unknown keys are an error so typos like
//! `justfication` cannot silently disarm the requirement.

use crate::rules::Violation;

#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
    pub contains: Option<String>,
    pub justification: String,
    /// Source line in the TOML file (for diagnostics).
    pub toml_line: usize,
}

impl AllowEntry {
    /// Does this entry suppress `v`? Path matches exactly or as a
    /// `/`-separated suffix, so entries stay stable if the lint root
    /// ever gains a prefix.
    pub fn matches(&self, v: &Violation) -> bool {
        if self.rule != v.rule {
            return false;
        }
        let path_ok = v.path == self.path
            || v.path.ends_with(&format!("/{}", self.path));
        if !path_ok {
            return false;
        }
        match &self.contains {
            Some(sub) => v.snippet.contains(sub.as_str()),
            None => true,
        }
    }
}

pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    pub fn empty() -> Self {
        Allowlist { entries: Vec::new() }
    }

    /// Load from a file path; a missing file is an empty allowlist
    /// (the fixtures lint without one), a malformed file is an error.
    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Self::parse(&text)
                .map_err(|e| format!("{}: {e}", path.display())),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Ok(Self::empty())
            }
            Err(e) => Err(format!("{}: {e}", path.display())),
        }
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let mut entries: Vec<AllowEntry> = Vec::new();
        let mut current: Option<PartialEntry> = None;

        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line == "[[allow]]" {
                if let Some(p) = current.take() {
                    entries.push(p.finish()?);
                }
                current = Some(PartialEntry::new(lineno));
                continue;
            }
            if line.starts_with('[') {
                return Err(format!(
                    "line {lineno}: unexpected table `{line}` — only \
                     [[allow]] entries are supported"
                ));
            }
            let Some((key, value)) = parse_kv(&line) else {
                return Err(format!(
                    "line {lineno}: expected `key = \"value\"`, got `{line}`"
                ));
            };
            let Some(entry) = current.as_mut() else {
                return Err(format!(
                    "line {lineno}: `{key}` outside an [[allow]] entry"
                ));
            };
            match key.as_str() {
                "rule" => entry.rule = Some(value),
                "path" => entry.path = Some(value),
                "contains" => entry.contains = Some(value),
                "justification" => entry.justification = Some(value),
                other => {
                    return Err(format!(
                        "line {lineno}: unknown key `{other}` (allowed: \
                         rule, path, contains, justification)"
                    ));
                }
            }
        }
        if let Some(p) = current.take() {
            entries.push(p.finish()?);
        }
        Ok(Allowlist { entries })
    }
}

struct PartialEntry {
    toml_line: usize,
    rule: Option<String>,
    path: Option<String>,
    contains: Option<String>,
    justification: Option<String>,
}

const RULES: [&str; 7] = ["D1", "D2", "D3", "D4", "D5", "S1", "S2"];

impl PartialEntry {
    fn new(toml_line: usize) -> Self {
        PartialEntry {
            toml_line,
            rule: None,
            path: None,
            contains: None,
            justification: None,
        }
    }

    fn finish(self) -> Result<AllowEntry, String> {
        let at = format!("[[allow]] at line {}", self.toml_line);
        let rule = self.rule.ok_or(format!("{at}: missing `rule`"))?;
        if !RULES.contains(&rule.as_str()) {
            return Err(format!(
                "{at}: unknown rule `{rule}` (expected one of {RULES:?})"
            ));
        }
        let path = self.path.ok_or(format!("{at}: missing `path`"))?;
        let justification = self
            .justification
            .ok_or(format!("{at}: missing `justification`"))?;
        if justification.trim().len() < 15 {
            return Err(format!(
                "{at}: justification `{justification}` is too short — \
                 state *why* this site cannot violate the invariant"
            ));
        }
        Ok(AllowEntry {
            rule,
            path,
            contains: self.contains,
            justification,
            toml_line: self.toml_line,
        })
    }
}

/// Drop a `#` comment, respecting quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut prev_backslash = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !prev_backslash => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        prev_backslash = c == '\\' && !prev_backslash;
    }
    line
}

/// Parse `key = "value"`. Only string values are supported.
fn parse_kv(line: &str) -> Option<(String, String)> {
    let (key, rest) = line.split_once('=')?;
    let key = key.trim();
    if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
    {
        return None;
    }
    let rest = rest.trim();
    let inner = rest.strip_prefix('"')?.strip_suffix('"')?;
    let mut value = String::new();
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next()? {
                'n' => value.push('\n'),
                't' => value.push('\t'),
                '"' => value.push('"'),
                '\\' => value.push('\\'),
                other => {
                    value.push('\\');
                    value.push(other);
                }
            }
        } else if c == '"' {
            return None; // unescaped quote mid-value: malformed
        } else {
            value.push(c);
        }
    }
    Some((key.to_string(), value))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn violation(rule: &'static str, path: &str, snippet: &str) -> Violation {
        Violation {
            rule,
            path: path.to_string(),
            line: 1,
            snippet: snippet.to_string(),
            message: String::new(),
        }
    }

    #[test]
    fn parses_entries_and_matches() {
        let toml = r#"
# repo allowlist
[[allow]]
rule = "S2"
path = "runtime/pool.rs"
contains = "expect"
justification = "lock poison means a worker panicked; aborting is correct"
"#;
        let allow = Allowlist::parse(toml).expect("parses");
        assert_eq!(allow.entries.len(), 1);
        let e = &allow.entries[0];
        assert!(e.matches(&violation("S2", "runtime/pool.rs", ".expect(")));
        assert!(e.matches(&violation("S2", "src/runtime/pool.rs", ".expect(")));
        assert!(!e.matches(&violation("S2", "runtime/pool.rs", ".unwrap(")));
        assert!(!e.matches(&violation("S1", "runtime/pool.rs", ".expect(")));
        assert!(!e.matches(&violation("S2", "my_runtime/pool.rs", ".expect(")));
    }

    #[test]
    fn missing_justification_is_rejected() {
        let toml = "[[allow]]\nrule = \"S2\"\npath = \"a.rs\"\n";
        let err = Allowlist::parse(toml).unwrap_err();
        assert!(err.contains("justification"), "{err}");
    }

    #[test]
    fn short_justification_is_rejected() {
        let toml = "[[allow]]\nrule = \"S2\"\npath = \"a.rs\"\n\
                    justification = \"ok\"\n";
        let err = Allowlist::parse(toml).unwrap_err();
        assert!(err.contains("too short"), "{err}");
    }

    #[test]
    fn unknown_key_is_rejected() {
        let toml = "[[allow]]\nrule = \"S2\"\npath = \"a.rs\"\n\
                    justfication = \"typo should not disarm the check\"\n";
        let err = Allowlist::parse(toml).unwrap_err();
        assert!(err.contains("unknown key"), "{err}");
    }

    #[test]
    fn unknown_rule_is_rejected() {
        let toml = "[[allow]]\nrule = \"Z9\"\npath = \"a.rs\"\n\
                    justification = \"this rule does not exist at all\"\n";
        let err = Allowlist::parse(toml).unwrap_err();
        assert!(err.contains("unknown rule"), "{err}");
    }

    #[test]
    fn comments_respect_quotes() {
        let toml = "[[allow]]\nrule = \"D2\"\npath = \"a.rs\"\n\
                    justification = \"the # here is not a comment marker\"\n";
        let allow = Allowlist::parse(toml).expect("parses");
        assert!(allow.entries[0].justification.contains('#'));
    }
}
