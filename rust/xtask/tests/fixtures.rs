//! Linter-on-the-linter: the fixture corpus pins the rule engine's
//! behavior (each rule has a bad snippet that must trip and an allowed
//! counterpart that must pass), and the repo tree itself must lint
//! clean against the real allowlist with no stale entries.

use std::collections::BTreeMap;
use std::path::PathBuf;

use xtask::allowlist::Allowlist;
use xtask::lint_tree;

fn fixtures(sub: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(sub)
}

#[test]
fn every_rule_trips_on_its_bad_fixture() {
    let outcome = lint_tree(&fixtures("bad"), &Allowlist::empty())
        .expect("bad corpus lints");

    let mut by_file: BTreeMap<String, Vec<&str>> = BTreeMap::new();
    for v in &outcome.violations {
        let rules = by_file.entry(v.path.clone()).or_default();
        if !rules.contains(&v.rule) {
            rules.push(v.rule);
        }
    }

    let expected: [(&str, &[&str]); 10] = [
        ("allocation/d1_float_sort.rs", &["D1"]),
        ("coding/d5_row_hasher.rs", &["D5"]),
        ("coordinator/d2_hash_iter.rs", &["D2"]),
        ("coordinator/d4_deadline_instant.rs", &["D4"]),
        ("workload/d3_thread_spawn.rs", &["D3"]),
        ("sim/d4_wall_clock.rs", &["D4"]),
        ("model/d5_adhoc_rng.rs", &["D5"]),
        ("coding/s1_unsafe.rs", &["S1"]),
        ("runtime/pool.rs", &["S1"]),
        ("workload/s2_unwrap.rs", &["S2"]),
    ];

    for (path, rules) in expected {
        assert_eq!(
            by_file.get(path).map(Vec::as_slice),
            Some(&rules[..]),
            "rules tripped by {path}"
        );
    }
    assert_eq!(
        by_file.len(),
        expected.len(),
        "unexpected extra findings: {by_file:?}"
    );
    assert!(outcome.suppressed.is_empty());
}

#[test]
fn allowed_fixtures_lint_clean() {
    let allow =
        Allowlist::load(&fixtures("allow.toml")).expect("fixture allowlist parses");
    let outcome =
        lint_tree(&fixtures("allowed"), &allow).expect("allowed corpus lints");

    assert!(
        outcome.violations.is_empty(),
        "allowed corpus must be clean, got: {:?}",
        outcome.violations
    );
    // Two HashMap mentions in the lookup-cache fixture plus one expect
    // in the allowlisted unwrap fixture.
    assert_eq!(outcome.suppressed.len(), 3, "suppressed findings");
    assert!(
        outcome.unused_entries.is_empty(),
        "every fixture allowlist entry must be exercised: {:?}",
        outcome.unused_entries
    );
}

#[test]
fn repo_tree_lints_clean_with_no_stale_allowlist_entries() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.join("../src");
    let allow = Allowlist::load(&manifest.join("lint_allow.toml"))
        .expect("repo allowlist parses");
    let outcome = lint_tree(&root, &allow).expect("repo tree lints");

    assert!(outcome.files > 50, "expected the full src tree, scanned {}", outcome.files);
    assert!(
        outcome.violations.is_empty(),
        "rust/src must lint clean:\n{}",
        outcome
            .violations
            .iter()
            .map(|v| format!("{}:{}: [{}] near `{}`", v.path, v.line, v.rule, v.snippet))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        outcome.unused_entries.is_empty(),
        "stale allowlist entries must be retired: {:?}",
        outcome.unused_entries
    );
}

#[test]
fn missing_justification_is_rejected() {
    let err = Allowlist::parse(
        "[[allow]]\nrule = \"S2\"\npath = \"runtime/pool.rs\"\n",
    )
    .unwrap_err();
    assert!(err.contains("justification"), "{err}");
}
