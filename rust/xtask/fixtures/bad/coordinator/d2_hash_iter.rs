//! D2 fixture: hash-map state in an order-sensitive tree — must trip.

use std::collections::HashMap;

pub struct Registry {
    pub workers: HashMap<String, f64>,
}

pub fn total(r: &Registry) -> f64 {
    r.workers.values().sum()
}
