//! D4 fixture (call form): hedge-deadline math against a private
//! wall-clock read in the coordinator — must trip. Deadlines and
//! timestamps must come from `runtime::wall_now()`, the one audited
//! `Instant::now` site in the crate; a direct read here would be
//! invisible to the recovery layer's determinism arguments.

use std::time::{Duration, Instant};

pub fn hedge_deadline_blown(deadline: Duration) -> bool {
    let armed = Instant::now();
    armed.elapsed() > deadline
}
