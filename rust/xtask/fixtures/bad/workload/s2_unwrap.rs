//! S2 fixture: unwrap and panic in non-test library code — must trip.

pub fn head(xs: &[u64]) -> u64 {
    *xs.first().unwrap()
}

pub fn reject(msg: &str) -> ! {
    panic!("rejected: {msg}");
}
