//! D3 fixture: ad-hoc thread creation outside runtime/pool.rs — must trip.

pub fn fan_out(n: usize) {
    let handles: Vec<_> =
        (0..n).map(|i| std::thread::spawn(move || i * 2)).collect();
    for h in handles {
        let _ = h.join();
    }
}
