//! D1 fixture: float sort via partial_cmp — must trip.

pub fn sort_loads(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
}
