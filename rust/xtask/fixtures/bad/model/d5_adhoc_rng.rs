//! D5 fixture: ambient-entropy randomness in model code — must trip.

use std::collections::hash_map::RandomState;
use std::hash::BuildHasher;

pub fn ambient_seed() -> u64 {
    RandomState::new().hash_one(0u64)
}
