//! D5 fixture: ad-hoc per-row coefficient hashing in coding code — must
//! trip. Deriving row randomness from `DefaultHasher` invents a private
//! mixing function: the hash is not covered by the seed-derivation
//! discipline, silently changes across std versions, and can never be
//! replayed from a recorded stream seed.

use std::hash::{DefaultHasher, Hash, Hasher};

pub fn row_coefficient(seed: u64, row: usize, col: usize) -> f64 {
    let mut h = DefaultHasher::new();
    (seed, row, col).hash(&mut h);
    (h.finish() as f64) / (u64::MAX as f64)
}
