//! S1 fixture: unsafe outside runtime/pool.rs — must trip even with an
//! adjacent SAFETY note, because location is the first half of the rule.

pub fn first_byte(xs: &[u8]) -> u8 {
    // SAFETY: caller guarantees xs is non-empty.
    unsafe { *xs.get_unchecked(0) }
}
