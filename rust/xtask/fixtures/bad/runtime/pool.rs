//! S1 fixture: the pool module itself with an unannotated unsafe block —
//! must trip. In-pool unsafe is allowed only when the lines just above it
//! document the invariant the block relies on; this one says nothing.

pub fn read_raw(p: *const u64) -> u64 {
    unsafe { *p }
}
