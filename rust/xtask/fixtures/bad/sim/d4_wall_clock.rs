//! D4 fixture: wall-clock read inside the simulator — must trip.

use std::time::Instant;

pub fn stamp_event() -> std::time::Duration {
    let t0 = Instant::now();
    t0.elapsed()
}
