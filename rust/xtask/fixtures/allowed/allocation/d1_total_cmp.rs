//! D1 counterpart: the sanctioned float ordering — must pass.

pub fn sort_loads(xs: &mut Vec<f64>) {
    xs.sort_by(f64::total_cmp);
}

pub fn argmin(xs: &[f64]) -> Option<usize> {
    (0..xs.len()).min_by(|&a, &b| xs[a].total_cmp(&xs[b]))
}
