//! S1/D3 counterpart: the one module allowed to own threads and unsafe
//! code, with every unsafe block annotated — must pass.

pub fn spawn_helper() -> std::thread::JoinHandle<()> {
    std::thread::spawn(|| {})
}

pub fn read_raw(p: *const u64) -> u64 {
    // SAFETY: callers pass a pointer derived from a live &u64; the
    // pointee outlives this call by construction.
    unsafe { *p }
}
