//! D4 counterpart: the simulator advances its own virtual clock — must
//! pass.

pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn advance(&mut self, dt: f64) -> f64 {
        self.now += dt;
        self.now
    }
}
