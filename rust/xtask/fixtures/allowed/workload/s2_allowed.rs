//! S2 allowlisted case: an expect whose invariant is established two
//! lines above — passes only because fixtures/allow.toml carries a
//! justified entry for this file.

pub fn head(xs: &[u64]) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    *xs.first().expect("non-empty checked above")
}
