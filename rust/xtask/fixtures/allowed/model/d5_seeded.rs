//! D5 counterpart: randomness flows from the seed-derivation helpers —
//! must pass. (`Rng::new` / `split` construction is fine anywhere; only
//! raw struct construction and ambient entropy are banned.)

pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed)
    }
}

pub fn stream(seed: u64, stream_id: u64) -> Rng {
    Rng::new(seed ^ stream_id.wrapping_mul(0x9e37_79b9_7f4a_7c15))
}
