//! S2 test exemption: unwraps inside #[cfg(test)] items never trip —
//! must pass with no allowlist entry at all.

pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwraps_are_fine_here() {
        let v: Option<u64> = Some(double(2));
        assert_eq!(v.unwrap(), 4);
        let parsed: u64 = "7".parse().expect("literal parses");
        assert_eq!(parsed, 7);
    }
}
