//! D2 allowlisted case: a HashMap used strictly as a point-lookup cache
//! (never iterated) — passes only because fixtures/allow.toml carries a
//! justified entry for this file.

use std::collections::HashMap;

pub struct LookupCache {
    map: HashMap<u64, Vec<f64>>,
}

impl LookupCache {
    pub fn get(&self, key: u64) -> Option<&Vec<f64>> {
        self.map.get(&key)
    }
}
