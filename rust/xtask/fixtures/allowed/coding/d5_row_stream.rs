//! D5 counterpart: the generator-extension idiom — must pass. Every
//! coefficient row derives its own stream from `(seed, row)` through the
//! documented splitmix-style mix, so materializing a prefix, extending
//! it later, or deriving one row on demand all read identical bits.

pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed)
    }

    pub fn normal(&mut self) -> f64 {
        self.0 = self.0.wrapping_add(1);
        self.0 as f64
    }
}

const ROW_MIX: u64 = 0x9e37_79b9_7f4a_7c15;

/// One coefficient row of the infinite stream: pure in `(seed, row)`,
/// independent of any shared cursor — the property that makes fountain
/// extension free of re-encodes.
pub fn derive_row(seed: u64, row: u64, k: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ (row + 1).wrapping_mul(ROW_MIX));
    let scale = 1.0 / (k as f64).sqrt();
    (0..k).map(|_| rng.normal() * scale).collect()
}

/// Extending the horizon replays the same per-row derivation for fresh
/// indices only; rows below the watermark are never touched.
pub fn extend(seed: u64, watermark: u64, new_n: u64, k: usize) -> Vec<Vec<f64>> {
    (watermark..new_n).map(|r| derive_row(seed, r, k)).collect()
}
