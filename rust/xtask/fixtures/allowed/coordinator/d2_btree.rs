//! D2 counterpart: ordered containers in an order-sensitive tree — must
//! pass without any allowlist entry.

use std::collections::BTreeMap;

pub struct Registry {
    pub workers: BTreeMap<String, f64>,
}

pub fn total(r: &Registry) -> f64 {
    r.workers.values().sum()
}
