//! Acceptance tests for the sharded admission front end (ISSUE 7):
//!
//! - **Parity** — the degenerate configuration (one shard, one tenant,
//!   stealing off, single-job batches) is bit-identical to the legacy
//!   FIFO path on a golden seed, in both layers: the model-time
//!   simulator against `simulate_queue`'s exact trace, and the live
//!   `Session` drain against plain `Mode::Arrivals` decoded outputs;
//! - **Scale** — a ≥1,000,000-arrival event-driven run across 4 shards
//!   with stealing and adaptive batching completes and is
//!   bit-reproducible from its seed (release builds; the debug-profile
//!   run is ignored by `cfg_attr` because the unoptimized event loop is
//!   too slow for the tier-1 suite);
//! - **SLO control** — across a mid-stream load step the adaptive
//!   controller keeps late-window p99 sojourn within the target while a
//!   fixed single-job drain violates it by a large factor;
//! - **Isolation** — a bursty tenant degrades a tame tenant's p99 by no
//!   more than a bounded factor under weighted DRR, and the burst's
//!   queueing lands on the burster itself.

use hetcoded::allocation::{policy, uniform_allocation, Allocation};
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{
    FrontEndConfig, JobConfig, JobReport, Mode, NativeCompute, Session,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use hetcoded::sim::Scheme;
use hetcoded::workload::{
    mean_service, run_admission, service_sampler, simulate_admission,
    simulate_queue, AdmissionConfig, AdmissionJob, ArrivalProcess, BatchPolicy,
    SloConfig, TenantSpec,
};
use std::sync::Arc;
use std::time::Duration;

fn small_spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

/// Mean single-job service time of the proposed policy on `small_spec`,
/// estimated from a dedicated deterministic stream.
fn mean_service_small() -> f64 {
    let (_, mut sampler) =
        service_sampler(&small_spec(), Scheme::Proposed, LatencyModel::A)
            .unwrap();
    mean_service(&mut sampler, 4_000, 7)
}

/// Nearest-rank p99 over the sojourns of jobs `lo..` in a trace.
fn late_p99(arrivals: &[f64], finishes: &[f64], lo: usize) -> f64 {
    let mut s: Vec<f64> = (lo..arrivals.len())
        .map(|i| finishes[i] - arrivals[i])
        .collect();
    assert!(!s.is_empty());
    s.sort_by(f64::total_cmp);
    let rank = ((0.99 * s.len() as f64).ceil() as usize).clamp(1, s.len());
    s[rank - 1]
}

#[test]
fn sim_fifo_parity_is_bit_identical_on_golden_seed() {
    // Golden-seed pin of the determinism contract: the degenerate
    // admission config replays the legacy RNG discipline exactly —
    // `Rng::new(seed)`, arrivals from the first split, service from the
    // second — so every start and finish is bit-equal to
    // `simulate_queue` on the same trace.
    let spec = small_spec();
    let golden = 0x6A11_D5EEDu64;
    let arrivals_spec = ArrivalProcess::Poisson { rate: 2.5 };
    for servers in [1usize, 2] {
        let cfg =
            AdmissionConfig::fifo_parity(arrivals_spec, 800, servers, golden);
        let p = policy::resolve("proposed").unwrap();
        let adm = run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();

        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let mut root = Rng::new(golden);
        let mut arrival_rng = root.split();
        let mut service_rng = root.split();
        let times = arrivals_spec.times(800, &mut arrival_rng).unwrap();
        let legacy =
            simulate_queue(&times, &mut sampler, servers, &mut service_rng)
                .unwrap();

        assert_eq!(adm.arrivals, legacy.arrivals, "servers {servers}");
        assert_eq!(adm.starts, legacy.starts, "servers {servers}");
        assert_eq!(adm.finishes, legacy.finishes, "servers {servers}");
        assert_eq!(adm.drainer_of, legacy.server_of, "servers {servers}");
        assert_eq!(adm.batches, 800, "single-job batches only");
        assert_eq!(adm.steals, 0);
        assert_eq!(adm.mean_batch, 1.0);
    }
}

/// The deterministic projection of a job report (wall clock excluded).
fn job_key(j: &JobReport) -> (Vec<f64>, Option<f64>, usize, usize, usize) {
    (
        j.decoded.clone(),
        j.model_latency,
        j.workers_used,
        j.rows_collected,
        j.n,
    )
}

#[test]
fn live_front_end_degenerate_matches_plain_arrivals_bit_for_bit() {
    // Live-layer parity: a session with the degenerate front end attached
    // must produce bit-identical decoded outputs, row counts, and encode
    // counts to the plain arrivals drain. All-zero offsets make batch
    // composition (4, 4, 4) independent of wall-clock timing, so the two
    // drains see identical batches and identical per-batch straggle
    // seeds.
    let spec = small_spec();
    let alloc: Allocation =
        uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let mut rng = Rng::new(0xF207);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let reqs: Vec<Vec<f64>> =
        (0..12).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
    let cfg = JobConfig { time_scale: 0.002, seed: 0x90_1D, ..Default::default() };
    let offsets: Vec<Duration> = vec![Duration::ZERO; 12];
    let serve = |front: Option<FrontEndConfig>| {
        let mut b = Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .config(cfg.clone())
            .compute(Arc::new(NativeCompute))
            .mode(Mode::Arrivals { offsets: offsets.clone(), max_batch: 4 });
        if let Some(f) = front {
            b = b.front_end(f);
        }
        b.build().unwrap().serve().unwrap()
    };
    let plain = serve(None);
    let fronted = serve(Some(FrontEndConfig::fifo_parity()));
    assert_eq!(plain.jobs.len(), 12);
    assert_eq!(fronted.jobs.len(), 12);
    for (i, (x, y)) in plain.jobs.iter().zip(&fronted.jobs).enumerate() {
        assert_eq!(job_key(x), job_key(y), "job {i} diverged");
        assert!(
            x.max_error == y.max_error
                || (x.max_error.is_nan() && y.max_error.is_nan()),
            "job {i} max_error {} vs {}",
            x.max_error,
            y.max_error
        );
    }
    assert_eq!(plain.encodes, fronted.encodes);
    assert_eq!(plain.worst_error, fronted.worst_error);
    assert_eq!(fronted.post_setup_encodes, 0);
    assert!(plain.front_end.is_none());
    let front = fronted.front_end.expect("front-end report attached");
    assert_eq!(front.shards, 1);
    assert_eq!(front.tenants, 1);
    assert_eq!(front.batches, 3, "t = 0 arrivals batch as (4, 4, 4)");
    assert_eq!(front.cross_shard_batches, 0);
    assert_eq!(front.max_batch_used, 4);
    assert_eq!(front.final_batch_limit, 4, "mode max_batch is the limit");
    // The live steals counter mirrors the admission simulator's: under
    // the degenerate single-shard config neither layer can ever drain a
    // non-home shard, and the two counts are equal (both provably 0).
    let sim_cfg = AdmissionConfig::fifo_parity(
        ArrivalProcess::Poisson { rate: 2.5 },
        100,
        1,
        0x90_1D,
    );
    let p = policy::resolve("proposed").unwrap();
    let adm = run_admission(&spec, &*p, LatencyModel::A, &sim_cfg).unwrap();
    assert_eq!(front.steals, adm.steals);
    assert_eq!(front.steals, 0);
}

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "1M-arrival event loop needs the release profile; run with \
              `cargo test --release`"
)]
fn million_arrivals_across_four_shards_are_deterministic() {
    // The scale proof: 1,000,000 arrivals from 8 Poisson tenants across
    // 4 shards with work stealing and SLO-adaptive batching, run twice
    // from the same seed — every completion time, drainer assignment,
    // steal count, and queue-depth peak must be bit-identical.
    let spec = small_spec();
    let cfg = AdmissionConfig {
        tenants: (0..8)
            .map(|_| TenantSpec {
                arrivals: ArrivalProcess::Poisson { rate: 2.0 },
                weight: 1.0,
            })
            .collect(),
        jobs: 1_000_000,
        shards: 4,
        drainers: 4,
        steal: true,
        batch: BatchPolicy::Adaptive(SloConfig {
            target_p99: 2.0,
            ..Default::default()
        }),
        amortize: 0.75,
        seed: 0x1E6_A112,
    };
    let p = policy::resolve("proposed").unwrap();
    let a = run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();
    let b = run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();
    assert_eq!(a.jobs, 1_000_000);
    assert_eq!(a.starts, b.starts);
    assert_eq!(a.finishes, b.finishes);
    assert_eq!(a.drainer_of, b.drainer_of);
    assert_eq!(a.steals, b.steals);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.max_queue_depth, b.max_queue_depth);
    assert_eq!(
        a.sojourn_percentile(99.0).to_bits(),
        b.sojourn_percentile(99.0).to_bits()
    );
    // The run actually exercised the machinery it claims to prove.
    assert!(a.batches < 1_000_000, "batching never engaged");
    assert!(a.mean_batch > 1.0);
    assert!(a.makespan > 0.0);
    for t in 0..8 {
        assert!(a.per_tenant_sojourn[t].count() > 100_000, "tenant {t} starved");
    }
}

#[test]
fn adaptive_batching_holds_slo_through_a_load_step_where_fixed_cannot() {
    // Mid-stream load step: a long warm phase at 0.5 job per E[S], then a
    // 3-per-E[S] flood — 3x the single-job service capacity, but well
    // inside the amortized capacity (γ = 0.75: a b-job batch costs
    // S·(0.75 + 0.25·b), so wide batches serve up to ~4 jobs per E[S]).
    // The adaptive controller must grow the limit and keep the
    // late-window p99 within the SLO; the fixed single-job drain
    // accumulates ~2 jobs of backlog per E[S] and blows through it by
    // orders of magnitude (asserted at a conservative 4x).
    let spec = small_spec();
    let es = mean_service_small();
    let warm = 1_000usize;
    let flood = 5_000usize;
    let mut jobs: Vec<AdmissionJob> = Vec::with_capacity(warm + flood);
    for i in 0..warm {
        jobs.push(AdmissionJob { arrival: i as f64 * 2.0 * es, tenant: 0 });
    }
    let step_at = warm as f64 * 2.0 * es;
    for j in 0..flood {
        jobs.push(AdmissionJob {
            arrival: step_at + j as f64 * es / 3.0,
            tenant: 0,
        });
    }
    let target = 25.0 * es;
    let mk = |batch| AdmissionConfig {
        tenants: vec![TenantSpec {
            arrivals: ArrivalProcess::Deterministic { rate: 1.0 },
            weight: 1.0,
        }],
        jobs: jobs.len(),
        shards: 1,
        drainers: 1,
        steal: false,
        batch,
        amortize: 0.75,
        seed: 0x510,
    };
    let run = |batch| {
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let mut rng = Rng::new(0xCAFE);
        simulate_admission(&jobs, &mut sampler, &mk(batch), &mut rng).unwrap()
    };
    let adaptive = run(BatchPolicy::Adaptive(SloConfig {
        target_p99: target,
        min_batch: 1,
        max_batch: 64,
        window: 64,
        decide_every: 16,
    }));
    let fixed = run(BatchPolicy::Fixed(1));
    // Late window: the last 2000 flood jobs, long after the step's
    // transient (the controller reaches a sufficient limit within ~100
    // completions of the step).
    let lo = warm + flood - 2_000;
    let adaptive_p99 = late_p99(&adaptive.arrivals, &adaptive.finishes, lo);
    let fixed_p99 = late_p99(&fixed.arrivals, &fixed.finishes, lo);
    assert!(
        adaptive_p99 <= target,
        "adaptive late-window p99 {adaptive_p99:.3} must hold the SLO \
         {target:.3} (final limit {}, grows {})",
        adaptive.final_batch_limit,
        adaptive.batch_grows
    );
    assert!(
        fixed_p99 >= 4.0 * target,
        "fixed single-job drain should blow the SLO by >= 4x under a 3x \
         overload, got p99 {fixed_p99:.3} vs target {target:.3}"
    );
    // The controller actually steered: it grew past single-job batches
    // and the drain used wide batches during the flood.
    assert!(adaptive.batch_grows >= 1, "no grow decisions");
    assert!(adaptive.max_batch_used >= 4, "flood never batched");
    assert!(adaptive.mean_batch > 1.0);
    assert_eq!(fixed.batch_grows, 0);
    assert_eq!(fixed.max_batch_used, 1);
}

#[test]
fn drr_bounds_bursty_neighbor_damage_to_a_tame_tenant() {
    // Two tenants share one shard and one drainer under weighted DRR.
    // Tenant 0 is tame (Poisson at 1 job per E[S]); tenant 1 either
    // matches the same long-run rate smoothly or delivers it in ON/OFF
    // bursts at 6 jobs per E[S]. The burst must queue on the burster:
    // tenant 0's p99 may degrade by at most a bounded factor, while the
    // bursty tenant's own p99 dwarfs its neighbour's.
    let spec = small_spec();
    let es = mean_service_small();
    let tame = TenantSpec {
        arrivals: ArrivalProcess::Poisson { rate: 1.0 / es },
        weight: 1.0,
    };
    let mk = |neighbor| AdmissionConfig {
        tenants: vec![tame, neighbor],
        jobs: 4_000,
        shards: 1,
        drainers: 1,
        steal: false,
        batch: BatchPolicy::Fixed(8),
        amortize: 0.75,
        seed: 0xB025_7,
    };
    let p = policy::resolve("proposed").unwrap();
    let smooth = run_admission(
        &spec,
        &*p,
        LatencyModel::A,
        &mk(TenantSpec {
            arrivals: ArrivalProcess::Poisson { rate: 3.0 / es },
            weight: 1.0,
        }),
    )
    .unwrap();
    let bursty = run_admission(
        &spec,
        &*p,
        LatencyModel::A,
        &mk(TenantSpec {
            arrivals: ArrivalProcess::OnOff {
                rate_on: 6.0 / es,
                mean_on: 40.0 * es,
                mean_off: 40.0 * es,
            },
            weight: 1.0,
        }),
    )
    .unwrap();
    let tame_baseline = smooth.tenant_percentile(0, 99.0);
    let tame_under_burst = bursty.tenant_percentile(0, 99.0);
    let burster = bursty.tenant_percentile(1, 99.0);
    assert!(
        tame_under_burst <= 10.0 * tame_baseline,
        "bursty neighbour degraded the tame tenant's p99 beyond the \
         isolation bound: {tame_under_burst:.3} vs baseline \
         {tame_baseline:.3}"
    );
    assert!(
        burster >= 2.0 * tame_under_burst,
        "the burst's queueing must land on the burster: burster p99 \
         {burster:.3} vs tame {tame_under_burst:.3}"
    );
    // Sanity: both runs completed every job and actually batched.
    assert_eq!(smooth.jobs, 4_000);
    assert_eq!(bursty.jobs, 4_000);
    assert!(bursty.mean_batch > 1.0, "burst never batched");
}
