//! Golden bit-identity fixtures for the `Code` trait refactor.
//!
//! The trait's default methods are documented as *delegation*, not
//! reimplementation: `setup` → `Generator::new`, `encode` →
//! `Encoder::encode_capped`, `decode_rows` → `Decoder::decode_batch`.
//! These tests pin that claim at the bit level, so any future `Code`
//! implementation that silently forks the dense path fails here:
//!
//! - component level: the trait path and the raw pre-trait call chain
//!   produce byte-identical coded matrices and decoded columns for the
//!   dense Vandermonde and systematic-random generators;
//! - session level: a `Session` that names a code through the registry
//!   serves bit-identically to one that resolves the same generator the
//!   pre-registry way (`JobConfig::generator`, `code: None`);
//! - fixture level: the systematic prefix of every systematic generator
//!   equals the input rows exactly, and an FNV-1a digest of the coded
//!   matrix is invariant across pool sizes and repeat encodes.

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::code;
use hetcoded::coding::{Decoder, Encoder, Generator, GeneratorKind, Matrix};
use hetcoded::coordinator::{JobConfig, Mode, NativeCompute, Session};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use hetcoded::runtime::pool::WorkPool;
use std::sync::Arc;

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.normal())
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// FNV-1a over the bit patterns — the digest that anchors the fixture.
fn digest(m: &Matrix) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in m.data() {
        for byte in v.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

#[test]
fn trait_path_bit_identical_to_legacy_components() {
    let (n, k, d) = (96usize, 64usize, 8usize);
    let pool = WorkPool::new(2);
    for (name, kind) in [
        ("mds-vandermonde", GeneratorKind::Vandermonde),
        ("mds-random", GeneratorKind::SystematicRandom),
    ] {
        let code = code::resolve(name).unwrap();
        let a = random_matrix(k, d, 0x601D);

        // Legacy chain, exactly as the coordinator called it before the
        // registry existed.
        let legacy_gen = Generator::new(kind, n, k, 7).unwrap();
        let legacy_enc = Encoder::new(legacy_gen.clone());
        let legacy_coded = legacy_enc.encode_capped(&a, &pool, 2).unwrap();

        // Trait chain with identical inputs.
        let gen = code.setup(n, k, 7).unwrap();
        let encoder = Encoder::new(gen.clone());
        let coded = code.encode(&encoder, &a, &pool, 2).unwrap();

        assert_eq!(bits(&coded), bits(&legacy_coded), "{name}: encode forked");
        assert_eq!(
            bits(gen.matrix()),
            bits(legacy_gen.matrix()),
            "{name}: generator forked"
        );

        // Decode a scattered k-subset through both paths.
        let rows: Vec<usize> = (0..n).filter(|r| r % 3 != 1).take(k).collect();
        let x: Vec<f64> = (0..d).map(|j| 0.25 * (j as f64 + 1.0)).collect();
        let y = coded.matvec(&x);
        let col: Vec<f64> = rows.iter().map(|&r| y[r]).collect();
        let legacy_out = Decoder::new(legacy_gen)
            .decode_batch(&rows, &[col.clone()])
            .unwrap();
        let mut decoder = Decoder::new(gen);
        let out = code.decode_rows(&mut decoder, &rows, &[col]).unwrap();
        let same = out[0]
            .iter()
            .zip(&legacy_out[0])
            .all(|(p, q)| p.to_bits() == q.to_bits());
        assert!(same, "{name}: decode forked");
    }
}

#[test]
fn session_with_registry_code_serves_bit_identically_to_generator_config() {
    let spec = ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let a = random_matrix(64, 8, 0xF1C);
    let mut rng = Rng::new(0xF1D);
    let reqs: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    for (name, kind) in [
        ("mds-vandermonde", GeneratorKind::Vandermonde),
        ("mds-random", GeneratorKind::SystematicRandom),
    ] {
        let serve = |use_registry: bool| {
            let cfg = JobConfig {
                time_scale: 0.002,
                seed: 0x60A1,
                generator: kind,
                ..Default::default()
            };
            let mut b = Session::builder(&spec)
                .allocation(alloc.clone())
                .data(a.clone())
                .requests(reqs.clone())
                .config(cfg)
                .compute(Arc::new(NativeCompute))
                .mode(Mode::Batched);
            if use_registry {
                b = b.code(name);
            }
            b.build().unwrap().serve().unwrap()
        };
        let legacy = serve(false);
        let named = serve(true);
        assert_eq!(legacy.jobs.len(), named.jobs.len(), "{name}");
        for (i, (x, y)) in legacy.jobs.iter().zip(&named.jobs).enumerate() {
            assert_eq!(x.decoded, y.decoded, "{name}: job {i} decoded forked");
            assert_eq!(x.rows_collected, y.rows_collected, "{name}: job {i}");
        }
        assert_eq!(legacy.encodes, named.encodes, "{name}");
        assert!(
            legacy.worst_error == named.worst_error
                || (legacy.worst_error.is_nan() && named.worst_error.is_nan()),
            "{name}: worst_error {} vs {}",
            legacy.worst_error,
            named.worst_error
        );
        // Dense serving stays accurate after the refactor (Vandermonde at
        // k = 64 carries the serving-path tolerance, cf. prepared_path.rs).
        assert!(legacy.worst_error < 1e-2, "{name}: {}", legacy.worst_error);
    }
}

#[test]
fn systematic_prefix_is_the_input_matrix_bit_for_bit() {
    // The analytic fixture: every systematic code's first k coded rows ARE
    // the input rows — no arithmetic, no tolerance.
    let (n, k, d) = (48usize, 32usize, 5usize);
    let a = random_matrix(k, d, 0x575);
    for name in ["mds-random", "sparse-parity"] {
        let code = code::resolve(name).unwrap();
        let gen = code.setup(n, k, 11).unwrap();
        let encoder = Encoder::new(gen);
        let coded = code
            .encode(&encoder, &a, WorkPool::global_ref(), 1)
            .unwrap();
        for i in 0..k {
            for j in 0..d {
                assert_eq!(
                    coded.row(i)[j].to_bits(),
                    a.row(i)[j].to_bits(),
                    "{name}: systematic row {i} col {j}"
                );
            }
        }
    }
}

#[test]
fn rateless_digest_invariant_across_extension_schedules_and_pools() {
    // The fountain's bit-identity fixture: coded row `i` depends only on
    // `(seed, i)`, so materializing [0, 2n) in one `encode_rows` call,
    // splitting at the setup boundary, or dribbling 4-row packets (the
    // streaming loop's mint pattern) must produce byte-identical rows —
    // at every pool size the suites pin elsewhere.
    let (n, k, d) = (48usize, 32usize, 6usize);
    let a = random_matrix(k, d, 0xD17);
    let code = code::resolve("rateless-rlc").unwrap();
    let gen = code.setup(n, k, 17).unwrap();
    let reference = {
        let encoder = Encoder::new(gen.clone());
        let m = code
            .encode_rows(&encoder, &a, 0..2 * n, &WorkPool::new(1), 1)
            .unwrap();
        digest(&m)
    };
    for threads in [1usize, 2, 7, 16] {
        let pool = WorkPool::new(threads);
        // Split at the setup boundary.
        let encoder = Encoder::new(gen.clone());
        let head = code.encode_rows(&encoder, &a, 0..n, &pool, 2).unwrap();
        let tail =
            code.encode_rows(&encoder, &a, n..2 * n, &pool, 2).unwrap();
        let mut stitched = head.clone();
        for r in 0..tail.rows() {
            stitched.push_row(tail.row(r)).unwrap();
        }
        assert_eq!(
            digest(&stitched),
            reference,
            "boundary split forked at pool={threads}"
        );
        // Packet-sized dribble, the streaming loop's worst case.
        let encoder = Encoder::new(gen.clone());
        let mut dribble: Option<Matrix> = None;
        let mut at = 0usize;
        while at < 2 * n {
            let end = (at + 4).min(2 * n);
            let piece =
                code.encode_rows(&encoder, &a, at..end, &pool, 2).unwrap();
            match dribble.as_mut() {
                None => dribble = Some(piece),
                Some(m) => {
                    for r in 0..piece.rows() {
                        m.push_row(piece.row(r)).unwrap();
                    }
                }
            }
            at = end;
        }
        assert_eq!(
            digest(&dribble.unwrap()),
            reference,
            "packet dribble forked at pool={threads}"
        );
        assert_eq!(encoder.re_encoded_rows(), 0);
    }
}

#[test]
fn coded_digest_invariant_across_pool_sizes_and_repeats() {
    // The digest fixture: one number per registered code that moves if any
    // bit of the coded matrix moves — across pool sizes, stream caps, and
    // repeat encodes.
    let (n, k, d) = (96usize, 64usize, 8usize);
    let a = random_matrix(k, d, 0xD16);
    for e in code::entries() {
        let code = e.build();
        let gen = code.setup(n, k, 13).unwrap();
        let encoder = Encoder::new(gen);
        let reference =
            digest(&code.encode(&encoder, &a, &WorkPool::new(1), 1).unwrap());
        for threads in [1usize, 2, 7, 16] {
            let pool = WorkPool::new(threads);
            for streams in [1usize, 3, 16] {
                let got =
                    digest(&code.encode(&encoder, &a, &pool, streams).unwrap());
                assert_eq!(
                    got, reference,
                    "{}: digest moved at pool={threads} streams={streams}",
                    e.name
                );
            }
        }
    }
}
