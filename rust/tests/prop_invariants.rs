//! Property-based tests over the paper's invariants, driven by the in-repo
//! property harness (`hetcoded::proptest`) on randomly generated clusters.

use hetcoded::allocation::{
    group_code_allocation, proposed_allocation, reisizadeh_allocation,
    uniform_allocation,
};
use hetcoded::coding::{decoder::roundtrip_check, Generator, GeneratorKind, Matrix};
use hetcoded::model::{order_stats, LatencyModel};
use hetcoded::proptest::{gen, property, DEFAULT_CASES};

#[test]
fn prop_mds_recovery_constraint_eq5() {
    // Σ_j r*_j l*_j = k for every random cluster.
    property("eq5", DEFAULT_CASES, |rng| {
        let spec = gen::cluster(rng, 6, 500, 10_000);
        let a = proposed_allocation(LatencyModel::A, &spec)
            .map_err(|e| format!("{e}"))?;
        let sum: f64 = a.r.iter().zip(&a.loads).map(|(r, l)| r * l).sum();
        let k = spec.k as f64;
        if (sum - k).abs() > 1e-6 * k {
            return Err(format!("sum r*l = {sum}, k = {k}"));
        }
        Ok(())
    });
}

#[test]
fn prop_group_latencies_equalized_theorem_1() {
    property("theorem1", DEFAULT_CASES, |rng| {
        let spec = gen::cluster(rng, 5, 300, 5_000);
        let a = proposed_allocation(LatencyModel::A, &spec)
            .map_err(|e| format!("{e}"))?;
        let t = a.latency_bound.unwrap();
        for (j, g) in spec.groups.iter().enumerate() {
            let lam = order_stats::group_latency(
                LatencyModel::A,
                a.loads[j],
                spec.k as f64,
                g.n as f64,
                a.r[j],
                g.mu,
                g.alpha,
            );
            if (lam - t).abs() > 1e-8 * t {
                return Err(format!("group {j}: λ = {lam} vs T* = {t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_r_star_strictly_interior() {
    property("r interior", DEFAULT_CASES, |rng| {
        let spec = gen::cluster(rng, 6, 400, 2_000);
        let a = proposed_allocation(LatencyModel::A, &spec)
            .map_err(|e| format!("{e}"))?;
        for (r, g) in a.r.iter().zip(&spec.groups) {
            if !(*r > 0.0 && *r < g.n as f64) {
                return Err(format!("r = {r} outside (0, {})", g.n));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_proposed_bound_below_uniform_bounds() {
    // T* is a lower bound: no uniform allocation can have an analytic
    // per-group latency below it at the same operating point. We check the
    // weaker (but simulation-free) statement that the proposed n* produces
    // positive finite loads and a positive bound.
    property("bound sane", DEFAULT_CASES, |rng| {
        let spec = gen::cluster(rng, 4, 300, 5_000);
        let a = proposed_allocation(LatencyModel::A, &spec)
            .map_err(|e| format!("{e}"))?;
        let t = a.latency_bound.unwrap();
        if !(t > 0.0 && t.is_finite()) {
            return Err(format!("bad bound {t}"));
        }
        if a.n < spec.k as f64 {
            return Err(format!("n = {} < k", a.n));
        }
        a.validate(&spec).map_err(|e| format!("{e}"))
    });
}

#[test]
fn prop_reisizadeh_equals_proposed() {
    // Structural identity (Appendix D vs Theorem 2) on random clusters.
    property("rz == proposed", DEFAULT_CASES, |rng| {
        let spec = gen::cluster(rng, 5, 300, 50_000);
        let a = proposed_allocation(LatencyModel::B, &spec)
            .map_err(|e| format!("{e}"))?;
        let z = reisizadeh_allocation(LatencyModel::B, &spec)
            .map_err(|e| format!("{e}"))?;
        for (x, y) in a.loads.iter().zip(&z.loads) {
            if (x - y).abs() > 1e-8 * x.max(1e-300) {
                return Err(format!("loads differ: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_group_code_consistent_when_alpha_equal() {
    property("group code eq29", 64, |rng| {
        let spec = gen::cluster_equal_alpha(rng, 4, 200, 5_000);
        let total = spec.total_workers() as f64;
        let r = 1.0 + rng.next_f64() * (total * 0.8 - 1.0);
        match group_code_allocation(LatencyModel::A, &spec, r) {
            Ok(a) => {
                let sum: f64 = a.r.iter().sum();
                if (sum - r).abs() > 1e-3 * r {
                    return Err(format!("Σ r_j = {sum} vs r = {r}"));
                }
                // Equalization (28) across all group pairs.
                let c0 = (spec.groups[0].n as f64
                    / (spec.groups[0].n as f64 - a.r[0]))
                    .ln()
                    / spec.groups[0].mu;
                for (j, g) in spec.groups.iter().enumerate().skip(1) {
                    let c = (g.n as f64 / (g.n as f64 - a.r[j])).ln() / g.mu;
                    if (c - c0).abs() > 1e-6 * c0.max(1e-12) {
                        return Err(format!("equalization broken at group {j}"));
                    }
                }
                Ok(())
            }
            Err(_) => Ok(()), // infeasible r is acceptable
        }
    });
}

#[test]
fn prop_uniform_rejects_infeasible_rate() {
    property("uniform domain", 64, |rng| {
        let spec = gen::cluster(rng, 3, 100, 1_000);
        // n < k must be rejected.
        let n_bad = spec.k as f64 * (0.2 + 0.7 * rng.next_f64());
        if uniform_allocation(LatencyModel::A, &spec, n_bad).is_ok() {
            return Err(format!("accepted n = {n_bad} < k"));
        }
        Ok(())
    });
}

#[test]
fn prop_decode_roundtrip_random_erasures() {
    // MDS decode recovers A·x from ANY k received rows (random construction).
    property("decode roundtrip", 48, |rng| {
        let k = 4 + rng.gen_range(12) as usize;
        let n = k + 1 + rng.gen_range(16) as usize;
        let d = 2 + rng.gen_range(6) as usize;
        let gen_mat = Generator::new(GeneratorKind::SystematicRandom, n, k, rng.next_u64())
            .map_err(|e| format!("{e}"))?;
        let a = Matrix::from_fn(k, d, |_, _| rng.normal());
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut rows: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut rows);
        let take = k + rng.gen_range((n - k) as u64 + 1) as usize;
        let err = roundtrip_check(&gen_mat, &a, &x, &rows[..take])
            .map_err(|e| format!("{e}"))?;
        if err > 1e-6 {
            return Err(format!("decode error {err} (k={k} n={n})"));
        }
        Ok(())
    });
}

#[test]
fn prop_scaling_identity_t_star() {
    // T*(c·N) = T*(N)/c for integer-preserving scalings.
    property("t* scaling", 64, |rng| {
        let spec = gen::cluster(rng, 4, 200, 2_000);
        let t1 = hetcoded::allocation::optimal_latency_bound(LatencyModel::A, &spec);
        let c = 1 + rng.gen_range(4) as usize; // integer factor keeps N_j exact
        let spec2 = spec.scaled_workers(c as f64);
        let t2 = hetcoded::allocation::optimal_latency_bound(LatencyModel::A, &spec2);
        if ((t1 / t2) / c as f64 - 1.0).abs() > 1e-9 {
            return Err(format!("T* ratio {} != {c}", t1 / t2));
        }
        Ok(())
    });
}
