//! Recovery-layer acceptance suite (deadline-driven hedged re-dispatch,
//! quarantine, graceful degradation).
//!
//! The headline scenario the PR must hold: a mid-batch stall of a whole
//! group plus 10% packet loss. With hedging on, every batch completes
//! exactly (zero re-encodes) and the worst wall latency stays within a
//! constant factor of a failure-free run; with hedging off, every
//! post-stall batch times out into the typed `Degraded` outcome at the
//! batch deadline — never a hang, never a panic.
//!
//! The determinism contract rides along: hedged decodes are bit-identical
//! across pool sizes and across hedge-timing schedules (first completion
//! wins, but the winning *values* are fixed by the row indices), and a
//! hedged session that never fires a hedge is bit-identical to a plain
//! one.

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::Matrix;
use hetcoded::coordinator::failures::{
    FailureEvent, FailureKind, FailureScenario,
};
use hetcoded::coordinator::{
    DegradePolicy, JobConfig, Mode, NativeCompute, RecoveryConfig,
    ServeOutcome, Session,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use hetcoded::runtime::pool::WorkPool;
use std::sync::Arc;
use std::time::Duration;

/// 4 fast + 6 slow workers, k = 64 — the smallest cluster where a whole
/// slow group can stall while the fast group still hedges it out.
fn two_group_spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

struct Run {
    code: &'static str,
    /// Total coded rows (64.0 = rate 1.0: every row is load-bearing).
    n: f64,
    events: Vec<FailureEvent>,
    recovery: Option<RecoveryConfig>,
    pool: Option<usize>,
    jobs: usize,
    max_batch: usize,
    time_scale: f64,
    seed: u64,
}

impl Default for Run {
    fn default() -> Self {
        Run {
            code: "mds-random",
            n: 128.0,
            events: Vec::new(),
            recovery: None,
            pool: None,
            jobs: 4,
            max_batch: 1,
            time_scale: 0.002,
            seed: 91,
        }
    }
}

fn serve(run: Run) -> hetcoded::Result<ServeOutcome> {
    let spec = two_group_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, run.n)?;
    let mut rng = Rng::new(run.seed);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let reqs: Vec<Vec<f64>> = (0..run.jobs)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    let offsets: Vec<Duration> = (0..run.jobs)
        .map(|i| Duration::from_millis(2 * i as u64))
        .collect();
    let cfg = JobConfig {
        time_scale: run.time_scale,
        seed: run.seed,
        ..Default::default()
    };
    let mut builder = Session::builder(&spec)
        .allocation(alloc)
        .code(run.code)
        .data(a)
        .requests(reqs)
        .config(cfg)
        .compute(Arc::new(NativeCompute))
        .scenario(FailureScenario::new(run.events)?)
        .mode(Mode::Arrivals { offsets, max_batch: run.max_batch });
    if let Some(rc) = run.recovery {
        builder = builder.recovery(rc);
    }
    if let Some(threads) = run.pool {
        builder = builder.pool(Arc::new(WorkPool::new(threads)));
    }
    builder.build()?.serve()
}

fn stall(at_batch: u64, workers: &[usize]) -> Vec<FailureEvent> {
    workers
        .iter()
        .map(|&worker| FailureEvent {
            at_batch,
            kind: FailureKind::StallWorker { worker },
        })
        .collect()
}

fn max_wall(outcome: &ServeOutcome) -> Duration {
    outcome.jobs.iter().map(|j| j.wall_latency).max().unwrap()
}

fn decoded_bits(outcome: &ServeOutcome) -> Vec<Vec<u64>> {
    outcome
        .jobs
        .iter()
        .map(|j| j.decoded.iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// The headline: group 1 (6 of 10 workers, holding > n-k rows) stalls
/// from batch 2 on while group 0's links drop 10% of packets. Hedged
/// serving completes every batch exactly with zero re-encodes and a tail
/// within 3x the failure-free run; the hedging-disabled arm times out
/// into `Degraded` at the batch deadline on every stalled batch, >= 5x
/// the clean tail.
#[test]
fn hedged_rides_out_a_mid_batch_group_stall_where_unhedged_degrades() {
    let scenario = || {
        let mut ev = stall(2, &[4, 5, 6, 7, 8, 9]);
        ev.push(FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyGroup { group: 0, p: 0.1 },
        });
        ev
    };
    // n = 96: the stalled group holds ~58 rows, so the 38 surviving rows
    // can never reach k = 64 without re-dispatch — and the fast group has
    // genuine spare MDS rows to hedge with.
    let base = || Run {
        n: 96.0,
        jobs: 6,
        time_scale: 0.05,
        seed: 92,
        ..Run::default()
    };
    let clean = serve(base()).unwrap();
    assert!(clean.worst_error < 1e-8, "err {}", clean.worst_error);
    let clean_max = max_wall(&clean);

    let hedged = serve(Run {
        events: scenario(),
        recovery: Some(RecoveryConfig {
            hedge_quantile: 0.8,
            deadline_floor: 0.01,
            ..Default::default()
        }),
        ..base()
    })
    .unwrap();
    let rec = hedged.recovery.as_ref().expect("recovery report");
    assert_eq!(hedged.recorder.count(), 6, "every batch completes");
    assert!(rec.degraded.is_empty(), "hedged run never degrades");
    assert!(hedged.worst_error < 1e-6, "err {}", hedged.worst_error);
    assert!(rec.counters.hedges_issued > 0, "stall must trigger hedges");
    assert!(rec.counters.hedge_wins > 0, "hedges must win stalled rows");
    // Zero re-encodes: hedges re-issue already-encoded spare rows.
    assert_eq!(hedged.encodes, 1);
    assert_eq!(hedged.post_setup_encodes, 0);
    let hedged_max = max_wall(&hedged);
    assert!(
        hedged_max <= clean_max * 3 + Duration::from_millis(30),
        "hedged tail {hedged_max:?} vs clean {clean_max:?}"
    );

    let unhedged = serve(Run {
        events: scenario(),
        recovery: Some(RecoveryConfig {
            hedge: false,
            hedge_quantile: 0.8,
            deadline_floor: 0.01,
            batch_deadline_factor: 8.0,
            degrade: DegradePolicy::Partial,
            ..Default::default()
        }),
        ..base()
    })
    .unwrap();
    let rec = unhedged.recovery.as_ref().expect("recovery report");
    assert_eq!(
        rec.counters.degraded_batches, 4,
        "every post-stall batch must degrade without hedging"
    );
    for d in &rec.degraded {
        assert!(d.batch >= 2, "pre-stall batch {} degraded", d.batch);
        assert!(d.deficit > 0 && d.deficit <= 64);
        assert!((d.error_bound - d.deficit as f64 / 64.0).abs() < 1e-12);
        // The typed outcome arrives at the batch deadline — bounded, and
        // far beyond anything the clean run ever waits.
        assert!(d.elapsed < Duration::from_secs(10), "runaway deadline");
        assert!(
            d.elapsed >= clean_max * 5,
            "unhedged degrade at {:?} is not >= 5x clean {clean_max:?}",
            d.elapsed
        );
    }
}

/// Decode bit-identity across pool sizes and hedge-timing schedules. At
/// rate 1.0 (n == k) every row is load-bearing, so a stalled worker's
/// rows *must* come back through hedges — and since hedge copies are
/// value-identical to the originals and the arena sorts by row index,
/// when the hedge fires or who computes the row cannot change a single
/// bit of the decode.
#[test]
fn hedged_decode_is_bit_identical_across_pools_and_schedules() {
    let schedules = [
        (0.9, 0.02, 1.5_f64),
        (0.5, 0.01, 2.0),
        (0.95, 0.5, 1.2),
    ];
    let run = |threads: usize, (q, floor, backoff): (f64, f64, f64)| {
        serve(Run {
            n: 64.0,
            events: stall(0, &[3]),
            recovery: Some(RecoveryConfig {
                hedge_quantile: q,
                deadline_floor: floor,
                backoff,
                ..Default::default()
            }),
            pool: Some(threads),
            jobs: 4,
            max_batch: 2,
            seed: 93,
            ..Run::default()
        })
        .unwrap()
    };
    let reference = run(1, schedules[0]);
    assert!(reference.worst_error < 1e-6);
    let rec = reference.recovery.as_ref().unwrap();
    assert!(rec.counters.hedges_issued > 0, "n == k forces hedging");
    let want = decoded_bits(&reference);
    for threads in [1, 2, 7, 16] {
        for schedule in schedules {
            let got = run(threads, schedule);
            assert!(got.recovery.as_ref().unwrap().degraded.is_empty());
            assert_eq!(
                decoded_bits(&got),
                want,
                "decode forked at pool={threads} schedule={schedule:?}"
            );
        }
    }
}

/// A hedged session that never fires a hedge (deadline floor far past any
/// batch) is bit-identical to a plain session: the recovery layer's
/// bookkeeping must not perturb the legacy arrival-order path.
#[test]
fn hedge_free_batches_are_bit_identical_to_the_unhedged_path() {
    let plain = serve(Run { jobs: 5, seed: 94, ..Run::default() }).unwrap();
    let hedged = serve(Run {
        jobs: 5,
        seed: 94,
        recovery: Some(RecoveryConfig {
            // 50 model-time units: orders of magnitude past any batch.
            deadline_floor: 50.0,
            ..Default::default()
        }),
        ..Run::default()
    })
    .unwrap();
    assert_eq!(decoded_bits(&plain), decoded_bits(&hedged));
    let c = hedged.recovery.unwrap().counters;
    assert_eq!(
        (c.hedges_issued, c.hedge_wins, c.wasted_rows, c.quarantines),
        (0, 0, 0, 0),
        "a quiet run must leave no recovery footprint"
    );
    assert!(plain.recovery.is_none(), "plain run reports no recovery");
}

/// Quarantine lifecycle through the live loop: a flapping worker (2 dark,
/// 2 healthy) at rate 1.0 blows its deadline in consecutive batches,
/// enters the ring, and the serving stream still decodes every batch
/// exactly because the quarantined chunk rides a zero-delay cover hedge.
#[test]
fn flapping_worker_is_quarantined_while_serving_stays_exact() {
    let outcome = serve(Run {
        n: 64.0,
        events: vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::FlappyWorker { worker: 8, period: 2 },
        }],
        recovery: Some(RecoveryConfig {
            quarantine_after: 2,
            ..Default::default()
        }),
        jobs: 12,
        seed: 95,
        ..Run::default()
    })
    .unwrap();
    assert_eq!(outcome.recorder.count(), 12);
    assert!(outcome.worst_error < 1e-6, "err {}", outcome.worst_error);
    let rec = outcome.recovery.unwrap();
    assert!(rec.degraded.is_empty());
    assert!(
        rec.counters.quarantines >= 1,
        "two consecutive dark batches must quarantine the flapper \
         (counters: {:?})",
        rec.counters
    );
    assert!(rec.counters.hedges_issued > 0);
    assert_eq!(rec.counters.degraded_batches, 0);
}

/// Every worker stalled: the batch deadline expires with zero rows. Under
/// `Partial` the run returns a typed degraded record (full deficit, error
/// bound 1.0, bounded wall time); under `Fail` it is an error. Neither
/// hangs.
#[test]
fn all_workers_stalled_degrades_instead_of_hanging() {
    let run = |degrade| Run {
        events: stall(0, &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9]),
        recovery: Some(RecoveryConfig {
            batch_deadline_factor: 4.0,
            degrade,
            ..Default::default()
        }),
        jobs: 2,
        max_batch: 2,
        seed: 96,
        ..Run::default()
    };
    let outcome = serve(run(DegradePolicy::Partial)).unwrap();
    let rec = outcome.recovery.as_ref().unwrap();
    assert_eq!(rec.counters.degraded_batches, 1);
    assert_eq!(rec.degraded.len(), 1);
    let d = &rec.degraded[0];
    assert_eq!(d.batch, 0);
    assert!(d.rows.is_empty(), "no worker ever replied");
    assert_eq!(d.deficit, 64);
    assert!((d.error_bound - 1.0).abs() < 1e-12);
    assert!(d.elapsed < Duration::from_secs(10), "deadline must bound it");
    // Placeholder reports keep the job count intact for the caller.
    assert_eq!(outcome.jobs.len(), 2);

    let err = serve(run(DegradePolicy::Fail))
        .err()
        .expect("Fail policy must surface an error, not hang");
    let msg = err.to_string();
    assert!(msg.contains("deadline") || msg.contains("degraded"), "{msg}");
}
