//! Lossy-link scenario coverage: the `--loss` CLI grammar, the
//! packet-filtered fixed-`n` collection, and composition with the
//! kill/slow/drift events that already ride [`FailureScenario`].
//!
//! The fountain-vs-MDS headline lives in `rateless.rs`; this suite pins
//! the scenario *plumbing*: parsing, deterministic per-packet fates
//! keyed by global row id, the redundancy arithmetic of the fixed-`n`
//! path, and the front-end incompatibility guard.

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::Matrix;
use hetcoded::coordinator::failures::{
    FailureEvent, FailureKind, FailureScenario,
};
use hetcoded::coordinator::{
    FrontEndConfig, JobConfig, Mode, NativeCompute, Session,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use std::sync::Arc;
use std::time::Duration;

fn two_group_spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

fn serve(
    code: &str,
    scenario: FailureScenario,
    jobs: usize,
    seed: u64,
) -> hetcoded::Result<hetcoded::coordinator::ServeOutcome> {
    let spec = two_group_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0)?;
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let reqs: Vec<Vec<f64>> = (0..jobs)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    let offsets: Vec<Duration> =
        (0..jobs).map(|i| Duration::from_millis(4 * i as u64)).collect();
    let cfg = JobConfig { time_scale: 0.002, seed, ..Default::default() };
    Session::builder(&spec)
        .allocation(alloc)
        .code(code)
        .data(a)
        .requests(reqs)
        .config(cfg)
        .compute(Arc::new(NativeCompute))
        .scenario(scenario)
        .mode(Mode::Arrivals { offsets, max_batch: 2 })
        .build()?
        .serve()
}

#[test]
fn loss_grammar_parses_both_dialects_and_rejects_garbage() {
    // Bernoulli form: BATCH:GROUP:P.
    let s = FailureScenario::parse_with_loss(None, None, Some("2:0:0.25"))
        .unwrap();
    assert!(s.has_loss());
    assert_eq!(s.events().len(), 1);
    assert!(matches!(
        s.events()[0].kind,
        FailureKind::LossyGroup { group: 0, p } if (p - 0.25).abs() < 1e-12
    ));
    assert_eq!(s.events()[0].at_batch, 2);

    // Burst form: BATCH:GROUP:burst:BATCHES, composed with kills and
    // drift in one script.
    let s = FailureScenario::parse_with_loss(
        Some("3:1,2"),
        Some("4:0:2.0"),
        Some("1:1:burst:5;6:0:0.1"),
    )
    .unwrap();
    assert!(s.has_loss());
    assert_eq!(s.events().len(), 4);
    assert!(s
        .events()
        .iter()
        .any(|e| matches!(
            e.kind,
            FailureKind::BurstDrop { group: 1, batches: 5 }
        )));

    // Loss-free scripts answer has_loss() = false.
    let s = FailureScenario::parse_with_loss(Some("3:1,2"), None, None)
        .unwrap();
    assert!(!s.has_loss());

    for bad in ["1:0", "1:0:burst", "1:0:burst:x", "a:0:0.5", "1:0:p"] {
        assert!(
            FailureScenario::parse_with_loss(None, None, Some(bad)).is_err(),
            "`{bad}` should be rejected"
        );
    }
}

#[test]
fn fixed_n_mds_rides_out_loss_inside_its_redundancy() {
    // Group 0 carries ~52 of 128 rows; even losing every one of its
    // packets leaves ~76 >= k = 64 from group 1, so a 30% Bernoulli drop
    // on group 0 alone can never push the collection sub-k. The MDS path
    // must serve every job exactly, no fountain required.
    let scenario = FailureScenario::new(vec![FailureEvent {
        at_batch: 0,
        kind: FailureKind::LossyGroup { group: 0, p: 0.3 },
    }])
    .unwrap();
    let outcome = serve("mds-random", scenario, 6, 31).unwrap();
    assert_eq!(outcome.recorder.count(), 6);
    assert!(outcome.worst_error < 1e-8, "err {}", outcome.worst_error);
    assert_eq!(outcome.encodes, 1);
    assert!(outcome.rateless.is_none(), "MDS never reports a summary");
}

#[test]
fn loss_composes_with_kills_and_drift_under_the_fountain() {
    // The full scenario algebra in one script: a kill, a group slowdown,
    // a Bernoulli-lossy link, and a burst window. The fountain absorbs
    // all four (the kill and the burst both just redirect issuance).
    let scenario = FailureScenario::new(vec![
        FailureEvent {
            at_batch: 1,
            kind: FailureKind::KillWorkers(vec![5]),
        },
        FailureEvent {
            at_batch: 1,
            kind: FailureKind::SlowGroup { group: 1, factor: 2.0 },
        },
        FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyGroup { group: 0, p: 0.2 },
        },
        FailureEvent {
            at_batch: 2,
            kind: FailureKind::BurstDrop { group: 0, batches: 1 },
        },
    ])
    .unwrap();
    let outcome = serve("rateless-rlc", scenario, 8, 32).unwrap();
    assert_eq!(outcome.recorder.count(), 8);
    assert!(outcome.worst_error < 1e-6, "err {}", outcome.worst_error);
    let rl = outcome.rateless.expect("fountain summary");
    assert!(rl.rows_received >= rl.batches * 64);
    assert_eq!(rl.re_encoded_rows, 0);
    assert_eq!(outcome.post_setup_encodes, 0);
}

#[test]
fn lossy_serving_is_bit_reproducible_from_the_seed() {
    // Packet fates are keyed by (stream seed, global row id), and the
    // round barrier sorts receipts by global row — so two fresh sessions
    // under the same lossy script decode bit-identical results.
    let scenario = || {
        FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyGroup { group: 0, p: 0.25 },
        }])
        .unwrap()
    };
    let run = || serve("rateless-rlc", scenario(), 5, 33).unwrap();
    let (first, second) = (run(), run());
    assert_eq!(first.jobs.len(), second.jobs.len());
    for (i, (x, y)) in first.jobs.iter().zip(&second.jobs).enumerate() {
        let same = x
            .decoded
            .iter()
            .zip(&y.decoded)
            .all(|(p, q)| p.to_bits() == q.to_bits());
        assert!(same, "job {i} decoded forked across reruns");
    }
    let (a, b) = (first.rateless.unwrap(), second.rateless.unwrap());
    assert_eq!(a, b, "streaming accounting forked across reruns");
}

#[test]
fn front_end_refuses_lossy_scenarios_up_front() {
    let spec = two_group_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let mut rng = Rng::new(34);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let reqs: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    let offsets: Vec<Duration> =
        (0..4).map(|i| Duration::from_millis(4 * i as u64)).collect();
    let scenario = FailureScenario::new(vec![FailureEvent {
        at_batch: 0,
        kind: FailureKind::LossyGroup { group: 0, p: 0.1 },
    }])
    .unwrap();
    let err = Session::builder(&spec)
        .allocation(alloc)
        .data(a)
        .requests(reqs)
        .config(JobConfig { time_scale: 0.002, ..Default::default() })
        .compute(Arc::new(NativeCompute))
        .scenario(scenario)
        .front_end(FrontEndConfig {
            shards: 2,
            tenants: 2,
            weights: Vec::new(),
            batch: None,
        })
        .mode(Mode::Arrivals { offsets, max_batch: 2 })
        .build()
        .err()
        .expect("front end + loss must be refused at build time");
    assert!(
        err.to_string().contains("front end"),
        "unexpected error: {err}"
    );
}
