//! Loom model of the `WorkPool` scope lifecycle (`runtime/pool.rs`).
//!
//! The pool's soundness argument (see the `ScopeState` doc comment)
//! rests on orderings the type system cannot check: the lifetime-erased
//! closure pointer is only dereferenced by a task claimed *before* the
//! completion latch fires, the caller's wake-up happens-after every
//! task's `done` increment, a stale helper dequeued after completion
//! never touches the scope, and a task panic is latched exactly once
//! and surfaced after the drain. This file re-implements that exact
//! synchronization skeleton — same atomics, same orderings (`Relaxed`
//! claim cursor, `AcqRel` completion counter, `Mutex` + `Condvar`
//! latch, `Mutex<Option<_>>` panic slot) — on loom's primitives, so
//! loom exhausts every interleaving and its race detector (via
//! `loom::cell::UnsafeCell` standing in for the erased closure memory)
//! proves the happens-before edges the comment claims.
//!
//! Only compiled under `RUSTFLAGS="--cfg loom"` (the CI `loom` job);
//! a plain `cargo test` builds this file as an empty binary.

#![cfg(loom)]

use loom::cell::UnsafeCell;
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// The modeled scope: `cell` stands in for the caller's stack-held
/// closure environment that `ScopeState::data` points at. Reads of it
/// model calls through the trampoline; the caller's post-latch write
/// models the stack frame being reused after `scope_run` returns.
struct ScopeModel {
    tasks: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panic: Mutex<Option<&'static str>>,
    finished: Mutex<bool>,
    cv: Condvar,
    cell: UnsafeCell<u64>,
}

// SAFETY (model): exactly the pool's own argument — the cell is read
// only by tasks claimed before the latch and written only after it;
// loom's race detector is the proof obligation for this impl.
unsafe impl Sync for ScopeModel {}

impl ScopeModel {
    fn new(tasks: usize) -> Self {
        ScopeModel {
            tasks,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panic: Mutex::new(None),
            finished: Mutex::new(false),
            cv: Condvar::new(),
            cell: UnsafeCell::new(7),
        }
    }

    /// `run_scope_tasks` verbatim: Relaxed claim, scope access, panic
    /// latch, AcqRel completion count, latch + notify on the last task.
    /// Returns the number of tasks this participant executed.
    fn drain(&self, poison: bool) -> usize {
        let mut ran = 0;
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.tasks {
                return ran;
            }
            ran += 1;
            // The trampoline call: a read of the closure environment.
            let v = self.cell.with(|p| unsafe { *p });
            assert_eq!(v, 7, "scope read after caller reclaimed the frame");
            if poison {
                let mut slot = self.panic.lock().unwrap();
                slot.get_or_insert("task panicked");
            }
            if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.tasks {
                let mut fin = self.finished.lock().unwrap();
                *fin = true;
                self.cv.notify_all();
            }
        }
    }

    /// The tail of `scope_run`: drain, block on the latch, then reclaim
    /// the closure memory (the caller's stack frame outliving the
    /// region is exactly what this write + loom's race check proves).
    fn finish(&self) -> Option<&'static str> {
        self.drain(false);
        let mut fin = self.finished.lock().unwrap();
        while !*fin {
            fin = self.cv.wait(fin).unwrap();
        }
        drop(fin);
        self.cell.with_mut(|p| unsafe { *p = 0 });
        self.panic.lock().unwrap().take()
    }
}

/// Every task runs exactly once, the caller's wake-up happens-after all
/// of them, and reclaiming the closure memory after the latch does not
/// race any helper's scope access.
#[test]
fn scope_completion_latch_is_sound() {
    loom::model(|| {
        let st = Arc::new(ScopeModel::new(3));
        let helper = {
            let st = Arc::clone(&st);
            thread::spawn(move || st.drain(false))
        };
        assert!(st.finish().is_none());
        let helper_ran = helper.join().unwrap();
        assert_eq!(st.done.load(Ordering::Relaxed), 3);
        assert!(helper_ran <= 3);
    });
}

/// A helper dequeued after the region completed claims an index >=
/// tasks and exits without touching the scope: with one task and two
/// helpers, at most one of them can ever read the cell, in every
/// interleaving — including those where the caller has already
/// reclaimed the frame before the late helper runs at all.
#[test]
fn stale_helper_exits_without_touching_scope() {
    loom::model(|| {
        let st = Arc::new(ScopeModel::new(1));
        let helpers: Vec<_> = (0..2)
            .map(|_| {
                let st = Arc::clone(&st);
                thread::spawn(move || st.drain(false))
            })
            .collect();
        st.finish();
        let ran: usize = helpers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(ran <= 1, "a stale helper re-ran a claimed task");
        assert_eq!(st.done.load(Ordering::Relaxed), 1);
    });
}

/// A panicking task still counts toward the latch (no hang), the first
/// payload is latched, and the caller observes it only after the drain
/// completes — the latch-and-rethrow path of `scope_run`.
#[test]
fn task_panic_is_latched_and_surfaced() {
    loom::model(|| {
        let st = Arc::new(ScopeModel::new(2));
        let helper = {
            let st = Arc::clone(&st);
            thread::spawn(move || st.drain(true))
        };
        let payload = st.finish_poisoned();
        helper.join().unwrap();
        assert_eq!(st.done.load(Ordering::Relaxed), 2);
        assert_eq!(payload, Some("task panicked"));
    });
}

impl ScopeModel {
    /// Caller variant whose own tasks also poison — so the payload is
    /// latched no matter which participant claims which task.
    fn finish_poisoned(&self) -> Option<&'static str> {
        self.drain(true);
        let mut fin = self.finished.lock().unwrap();
        while !*fin {
            fin = self.cv.wait(fin).unwrap();
        }
        drop(fin);
        self.cell.with_mut(|p| unsafe { *p = 0 });
        self.panic.lock().unwrap().take()
    }
}
