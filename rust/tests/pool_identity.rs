//! Pool-runtime acceptance suite (PR 5).
//!
//! Pins the two contracts the persistent compute pool must honor:
//!
//! 1. **Bit-identity**: every pooled kernel — matmul (dense and CSR
//!    sparse), encode under every registered generator family, multi-RHS
//!    decode, Monte-Carlo sweeps — produces byte-identical results across
//!    pool sizes {1, 2, 7, 16}, because the deterministic work partition
//!    and the index-ordered reduction are fixed by the caller, never by
//!    scheduling.
//! 2. **Pool reuse**: sessions share one pool without spawning threads per
//!    session or per batch (worker count is fixed at pool construction),
//!    and the steady-state serving loop performs zero big-buffer
//!    allocations after warm-up (`ServeOutcome::steady_allocs == 0`,
//!    measured, mirroring the `encodes == 1` pattern).

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::{CsrMatrix, Decoder, Encoder, Generator, GeneratorKind, Matrix};
use hetcoded::coordinator::{JobConfig, Mode, NativeCompute, Session};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use hetcoded::runtime::pool::WorkPool;
use hetcoded::sim::{monte_carlo_scratch_inner_on, AnyKSampler, SimConfig};
use std::sync::Arc;
use std::time::Duration;

const POOL_SIZES: [usize; 4] = [1, 2, 7, 16];

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.normal())
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn matmul_bit_identical_across_pool_sizes() {
    // Includes a zero-heavy systematic-style matrix, the case where the
    // register microkernel and the scalar fallback take different
    // zero-skip paths.
    let mut rng = Rng::new(1);
    for (m, k, n) in [(67, 130, 96), (256, 128, 64), (4, 4, 4)] {
        let a = Matrix::from_fn(m, k, |i, j| {
            if (i + j) % 3 == 0 {
                0.0
            } else {
                rng.normal()
            }
        });
        let b = random_matrix(k, n, 2 + m as u64);
        let reference = bits(&a.matmul_on(&b, &WorkPool::new(1)));
        for threads in POOL_SIZES {
            let pool = WorkPool::new(threads);
            let got = bits(&a.matmul_on(&b, &pool));
            assert_eq!(got, reference, "m={m} k={k} n={n} pool={threads}");
        }
    }
}

#[test]
fn encode_bit_identical_across_pool_sizes() {
    for kind in [
        GeneratorKind::SystematicRandom,
        GeneratorKind::Vandermonde,
        GeneratorKind::SparseParity,
    ] {
        let gen = Generator::new(kind, 192, 128, 7).unwrap();
        let a = random_matrix(128, 96, 3);
        let enc = Encoder::new(gen);
        let reference = bits(&enc.encode_on(&a, &WorkPool::new(1)).unwrap());
        for threads in POOL_SIZES {
            let pool = WorkPool::new(threads);
            let got = bits(&enc.encode_on(&a, &pool).unwrap());
            assert_eq!(got, reference, "{kind:?} pool={threads}");
        }
    }
}

#[test]
fn csr_matmul_bit_identical_to_dense_on_adversarial_patterns() {
    // The sparse kernel's determinism claim (`CsrMatrix::matmul_on` docs):
    // byte-equality with the dense kernel, at every pool size, on the
    // patterns where the two take maximally different paths — empty rows
    // (the CSR kernel writes nothing), one fully dense row (the CSR row
    // sweep degenerates to the dense one), a single-column matrix, a
    // single populated column, and the all-zero matrix — plus dimensions
    // that are not multiples of the register tile width.
    let mut rng = Rng::new(51);
    let dense_row = 7usize;
    let patterns: Vec<(&str, Matrix)> = vec![
        ("all-zero", Matrix::zeros(16, 20)),
        (
            "empty-rows",
            Matrix::from_fn(33, 20, |i, _| {
                if i % 3 == 0 {
                    0.0
                } else {
                    rng.normal()
                }
            }),
        ),
        (
            "one-dense-row",
            Matrix::from_fn(33, 20, |i, j| {
                if i == dense_row || (i + 3 * j) % 11 == 0 {
                    rng.normal()
                } else {
                    0.0
                }
            }),
        ),
        ("single-column-shape", Matrix::from_fn(19, 1, |_, _| rng.normal())),
        (
            "single-populated-column",
            Matrix::from_fn(19, 20, |_, j| {
                if j == 4 {
                    rng.normal()
                } else {
                    0.0
                }
            }),
        ),
    ];
    for (what, a) in &patterns {
        let csr = CsrMatrix::from_dense(a);
        // n = 13: not a multiple of the register tile width.
        for n in [1usize, 13, 64] {
            let b = random_matrix(a.cols(), n, 60 + n as u64);
            let reference = bits(&a.matmul_on(&b, &WorkPool::new(1)));
            for threads in POOL_SIZES {
                let pool = WorkPool::new(threads);
                assert_eq!(
                    bits(&csr.matmul_on(&b, &pool)),
                    reference,
                    "{what}: n={n} pool={threads}"
                );
                // The dense kernel agrees with itself too, so a failure
                // above is attributable to the sparse path.
                assert_eq!(
                    bits(&a.matmul_on(&b, &pool)),
                    reference,
                    "{what}: dense n={n} pool={threads}"
                );
            }
        }
    }
}

#[test]
fn decode_batch_bit_identical_across_pool_sizes() {
    let (n, k, b) = (192usize, 128usize, 32usize);
    let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 9).unwrap();
    let mut rng = Rng::new(11);
    let rows: Vec<usize> = (n - k..n).collect();
    let columns: Vec<Vec<f64>> = (0..b)
        .map(|_| (0..k).map(|_| rng.normal()).collect())
        .collect();
    let mut single = Decoder::new(gen.clone());
    let reference = single.decode_batch(&rows, &columns).unwrap();
    for threads in POOL_SIZES {
        let mut dec = Decoder::new(gen.clone());
        dec.set_pool(Some(Arc::new(WorkPool::new(threads))));
        let got = dec.decode_batch(&rows, &columns).unwrap();
        assert_eq!(got.len(), reference.len(), "pool={threads}");
        for (c, (gc, rc)) in got.iter().zip(&reference).enumerate() {
            let same = gc
                .iter()
                .zip(rc)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "pool={threads} column={c} diverged");
        }
    }
}

#[test]
fn monte_carlo_bit_identical_across_pool_sizes() {
    // cfg.threads fixes the deterministic stream split; the pool size is
    // pure execution and must be invisible in the summary.
    let spec = ClusterSpec::paper_two_group(1000);
    let loads = vec![2.5, 2.5];
    let base = AnyKSampler::new(&spec, &loads, LatencyModel::A).unwrap();
    for stream_count in [1usize, 3, 8] {
        let cfg = SimConfig { samples: 900, seed: 31, threads: stream_count };
        let reference = monte_carlo_scratch_inner_on(
            &WorkPool::new(1),
            &cfg,
            false,
            || base.clone(),
            |rng, s: &mut AnyKSampler| s.sample(rng),
        );
        for threads in POOL_SIZES {
            let pool = WorkPool::new(threads);
            let got = monte_carlo_scratch_inner_on(
                &pool,
                &cfg,
                false,
                || base.clone(),
                |rng, s: &mut AnyKSampler| s.sample(rng),
            );
            assert_eq!(
                got.mean().to_bits(),
                reference.mean().to_bits(),
                "streams={stream_count} pool={threads}"
            );
            assert_eq!(got.count(), reference.count());
            assert_eq!(got.max().to_bits(), reference.max().to_bits());
        }
    }
}

fn serving_spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

#[test]
fn sessions_share_one_pool_without_thread_leak() {
    let spec = serving_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let a = random_matrix(64, 8, 21);
    let mut rng = Rng::new(22);
    let requests: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    let cfg = JobConfig { time_scale: 0.002, ..Default::default() };

    let pool = Arc::new(WorkPool::new(3));
    assert_eq!(pool.spawned_workers(), 2);
    let build = |seed: u64| {
        Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(requests.clone())
            .config(JobConfig { seed, ..cfg.clone() })
            .compute(Arc::new(NativeCompute))
            .mode(Mode::Batched)
            .pool(Arc::clone(&pool))
            .build()
            .unwrap()
    };
    let s1 = build(100);
    let s2 = build(200);
    // Both sessions resolved to the same pool object.
    assert!(Arc::ptr_eq(s1.pool(), &pool));
    assert!(Arc::ptr_eq(s2.pool(), &pool));
    let o1 = s1.serve().unwrap();
    let o2 = s2.serve().unwrap();
    assert!(o1.worst_error < 1e-8 && o2.worst_error < 1e-8);
    // The introspection hook: serving through two sessions executed work
    // on the shared pool yet spawned nothing beyond the fixed worker set.
    assert_eq!(pool.spawned_workers(), 2, "thread leak: workers grew");
    assert!(pool.scopes_run() > 0, "sessions never used the shared pool");

    // A session with its own pool decodes to the same bits — pooling is
    // invisible in results.
    let own = Session::builder(&spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(requests.clone())
        .config(JobConfig { seed: 100, ..cfg.clone() })
        .compute(Arc::new(NativeCompute))
        .mode(Mode::Batched)
        .pool(Arc::new(WorkPool::new(7)))
        .build()
        .unwrap()
        .serve()
        .unwrap();
    for (j1, j2) in o1.jobs.iter().zip(&own.jobs) {
        assert_eq!(j1.decoded, j2.decoded);
    }
}

#[test]
fn encode_threads_hint_sizes_a_per_session_pool() {
    let spec = serving_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let a = random_matrix(64, 8, 31);
    let session = Session::builder(&spec)
        .allocation(alloc)
        .data(a)
        .requests(vec![vec![0.5; 8]])
        .config(JobConfig {
            time_scale: 0.002,
            encode_threads: 2,
            ..Default::default()
        })
        .mode(Mode::Single)
        .build()
        .unwrap();
    assert_eq!(session.pool().threads(), 2);
    // Without a hint, the shared global pool is used.
    let spec = serving_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let global = Session::builder(&spec)
        .allocation(alloc)
        .data(random_matrix(64, 8, 32))
        .requests(vec![vec![0.5; 8]])
        .config(JobConfig { time_scale: 0.002, ..Default::default() })
        .mode(Mode::Single)
        .build()
        .unwrap();
    assert!(Arc::ptr_eq(global.pool(), WorkPool::global()));
}

#[test]
fn arrivals_stream_serves_allocation_free_after_warmup() {
    // Three same-shaped batches with enough gap for each batch's
    // stragglers to drain: the first batch sizes every arena, and the
    // outcome proves nothing grew afterwards — alongside the existing
    // encodes == 1 invariant.
    let spec = serving_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let a = random_matrix(64, 8, 41);
    let mut rng = Rng::new(42);
    let requests: Vec<Vec<f64>> = (0..12)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    let offsets: Vec<Duration> = (0..12)
        .map(|i| Duration::from_millis(80 * (i as u64 / 4)))
        .collect();
    let outcome = Session::builder(&spec)
        .allocation(alloc)
        .data(a)
        .requests(requests)
        .config(JobConfig {
            time_scale: 0.002,
            verify_decode: false,
            ..Default::default()
        })
        .compute(Arc::new(NativeCompute))
        .mode(Mode::Arrivals { offsets, max_batch: 4 })
        .pool(Arc::new(WorkPool::new(4)))
        .build()
        .unwrap()
        .serve()
        .unwrap();
    assert_eq!(outcome.jobs.len(), 12);
    assert_eq!(outcome.encodes, 1, "prepared stream must encode once");
    assert_eq!(
        outcome.steady_allocs, 0,
        "steady-state batches allocated big buffers"
    );
}
