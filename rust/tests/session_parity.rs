//! API-parity tests for the `Session` redesign (ISSUE 4 acceptance):
//!
//! - each deprecated free-function shim (`run_job`, `run_job_batched`,
//!   `serve_requests`, `serve_requests_pipelined`, `serve_arrivals`,
//!   `serve_arrivals_adaptive`) produces **bit-identical** deterministic
//!   outputs to the equivalent `Session` configuration under a fixed
//!   seed — decoded vectors compared exactly, plus worker usage, row
//!   counts, model latencies, and the adaptation trace (wall-clock
//!   durations are the only fields excluded: they are real time);
//! - every policy name the CLI accepts resolves through the registry to
//!   exactly one `Policy`, and (since the `Code` registry mirrors it)
//!   every code name resolves to exactly one `Code`.
#![allow(deprecated)]

use hetcoded::allocation::{policy, uniform_allocation, Allocation, Policy};
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{
    run_job, run_job_batched, serve_arrivals, serve_arrivals_adaptive,
    serve_requests, serve_requests_pipelined, AdaptiveServeConfig,
    FailureEvent, FailureKind, FailureScenario, JobConfig, JobReport, Mode,
    NativeCompute, ServeOutcome, Session,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, EstimatorConfig, Group, LatencyModel};
use std::sync::Arc;
use std::time::Duration;

fn small_spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

fn redundant_alloc(spec: &ClusterSpec) -> Allocation {
    uniform_allocation(LatencyModel::A, spec, 128.0).unwrap()
}

fn data(jobs: usize, seed: u64) -> (Matrix, Vec<Vec<f64>>) {
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let reqs = (0..jobs)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    (a, reqs)
}

fn fast_cfg(seed: u64) -> JobConfig {
    JobConfig { time_scale: 0.002, seed, ..Default::default() }
}

/// The deterministic projection of a job report (everything except the
/// wall clock).
fn job_key(j: &JobReport) -> (Vec<f64>, Option<f64>, usize, usize, usize) {
    (
        j.decoded.clone(),
        j.model_latency,
        j.workers_used,
        j.rows_collected,
        j.n,
    )
}

fn assert_jobs_identical(a: &[JobReport], b: &[JobReport], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: job count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(job_key(x), job_key(y), "{what}: job {i} diverged");
        // max_error is either bit-equal or both NaN.
        assert!(
            x.max_error == y.max_error
                || (x.max_error.is_nan() && y.max_error.is_nan()),
            "{what}: job {i} max_error {} vs {}",
            x.max_error,
            y.max_error
        );
    }
}

fn session(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    reqs: &[Vec<f64>],
    cfg: &JobConfig,
    mode: Mode,
) -> ServeOutcome {
    Session::builder(spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(reqs.to_vec())
        .config(cfg.clone())
        .compute(Arc::new(NativeCompute))
        .mode(mode)
        .build()
        .unwrap()
        .serve()
        .unwrap()
}

#[test]
fn run_job_shim_matches_single_mode_session() {
    let spec = small_spec();
    let alloc = redundant_alloc(&spec);
    let (a, reqs) = data(1, 1001);
    let cfg = fast_cfg(0xD00D);
    let shim = run_job(
        &spec,
        &alloc,
        &a,
        &reqs[0],
        Arc::new(NativeCompute),
        &cfg,
    )
    .unwrap();
    let outcome = session(&spec, &alloc, &a, &reqs, &cfg, Mode::Single);
    assert_jobs_identical(&[shim], &outcome.jobs, "run_job");
    assert_eq!(outcome.encodes, 1);
}

#[test]
fn run_job_batched_shim_matches_batched_session() {
    let spec = small_spec();
    let alloc = redundant_alloc(&spec);
    let (a, reqs) = data(5, 1002);
    let cfg = fast_cfg(0xBA7C);
    let shim = run_job_batched(
        &spec,
        &alloc,
        &a,
        &reqs,
        Arc::new(NativeCompute),
        &cfg,
    )
    .unwrap();
    let outcome = session(&spec, &alloc, &a, &reqs, &cfg, Mode::Batched);
    assert_jobs_identical(&shim, &outcome.jobs, "run_job_batched");
    assert_eq!(outcome.encodes, 1);
    // One batch, one straggle realization: every request shares it.
    assert!(outcome
        .jobs
        .windows(2)
        .all(|w| w[0].workers_used == w[1].workers_used));
}

#[test]
fn serve_requests_shim_matches_sequential_session() {
    let spec = small_spec();
    let alloc = redundant_alloc(&spec);
    let (a, reqs) = data(6, 1003);
    let cfg = fast_cfg(0x5E9);
    let shim = serve_requests(
        &spec,
        &alloc,
        &a,
        &reqs,
        Arc::new(NativeCompute),
        &cfg,
    )
    .unwrap();
    let outcome = session(&spec, &alloc, &a, &reqs, &cfg, Mode::Sequential);
    assert_jobs_identical(&shim.jobs, &outcome.jobs, "serve_requests");
    assert_eq!(shim.encodes, outcome.encodes);
    assert_eq!(shim.worst_error, outcome.worst_error);
    // Documented legacy shape: no makespan on the sequential report; the
    // unified outcome always has one.
    assert!(shim.makespan.is_none());
    assert!(outcome.makespan.is_some());
}

#[test]
fn serve_requests_pipelined_shim_matches_pipelined_session() {
    let spec = small_spec();
    let alloc = redundant_alloc(&spec);
    let (a, reqs) = data(5, 1004);
    let cfg = fast_cfg(0x919E);
    let shim = serve_requests_pipelined(
        &spec,
        &alloc,
        &a,
        &reqs,
        Arc::new(NativeCompute),
        &cfg,
    )
    .unwrap();
    let outcome = session(&spec, &alloc, &a, &reqs, &cfg, Mode::Pipelined);
    assert_jobs_identical(&shim.jobs, &outcome.jobs, "serve_requests_pipelined");
    assert_eq!(shim.encodes, outcome.encodes);
    assert_eq!(shim.worst_error, outcome.worst_error);
    assert!(shim.makespan.is_some());
}

#[test]
fn serve_arrivals_shim_matches_arrivals_session() {
    let spec = small_spec();
    let alloc = redundant_alloc(&spec);
    let (a, reqs) = data(8, 1005);
    let cfg = fast_cfg(0xA3);
    // All requests arrive at t = 0 so batch composition (3, 3, 2) is
    // independent of wall-clock timing — the comparison must not race the
    // drain loop.
    let offsets: Vec<Duration> = vec![Duration::ZERO; 8];
    let shim = serve_arrivals(
        &spec,
        &alloc,
        &a,
        &reqs,
        &offsets,
        3,
        Arc::new(NativeCompute),
        &cfg,
    )
    .unwrap();
    let outcome = session(
        &spec,
        &alloc,
        &a,
        &reqs,
        &cfg,
        Mode::Arrivals { offsets: offsets.clone(), max_batch: 3 },
    );
    assert_jobs_identical(&shim.jobs, &outcome.jobs, "serve_arrivals");
    assert_eq!(shim.encodes, 1);
    assert_eq!(outcome.encodes, 1);
    assert_eq!(outcome.post_setup_encodes, 0);
    assert_eq!(shim.worst_error, outcome.worst_error);
}

#[test]
fn serve_arrivals_adaptive_shim_matches_adaptive_session() {
    let spec = small_spec();
    let alloc = redundant_alloc(&spec);
    let (a, reqs) = data(14, 1006);
    let cfg = fast_cfg(0xADA);
    let offsets: Vec<Duration> =
        (0..14).map(|i| Duration::from_millis(4 * i as u64)).collect();
    let scenario = FailureScenario::new(vec![FailureEvent {
        at_batch: 2,
        kind: FailureKind::KillWorkers(vec![0, 5]),
    }])
    .unwrap();
    let adapt = AdaptiveServeConfig {
        est: EstimatorConfig {
            min_obs: 1_000_000, // isolate the death path from drift noise
            check_every: 1,
            ..Default::default()
        },
        death_after: 3,
    };
    let shim = serve_arrivals_adaptive(
        &spec,
        &alloc,
        &a,
        &reqs,
        &offsets,
        1,
        Arc::new(NativeCompute),
        &cfg,
        &scenario,
        Some(&adapt),
    )
    .unwrap();
    let outcome = Session::builder(&spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(reqs.clone())
        .config(cfg.clone())
        .compute(Arc::new(NativeCompute))
        .scenario(scenario)
        .adaptive(adapt)
        .mode(Mode::Arrivals { offsets, max_batch: 1 })
        .build()
        .unwrap()
        .serve()
        .unwrap();
    assert_jobs_identical(
        &shim.serve.jobs,
        &outcome.jobs,
        "serve_arrivals_adaptive",
    );
    // The full adaptation trace must agree, bit for bit.
    assert_eq!(shim.reallocations, outcome.reallocations);
    assert_eq!(shim.rechunks, outcome.rechunks);
    assert_eq!(shim.suspected_dead, outcome.suspected_dead);
    assert_eq!(shim.post_setup_encodes, outcome.post_setup_encodes);
    assert_eq!(shim.serve.encodes, outcome.encodes);
    let shim_spec = &shim.assumed_spec;
    let sess_spec = outcome.assumed_spec.as_ref().unwrap();
    assert_eq!(shim_spec.k, sess_spec.k);
    assert_eq!(shim_spec.num_groups(), sess_spec.num_groups());
    for (x, y) in shim_spec.groups.iter().zip(&sess_spec.groups) {
        assert_eq!(x.n, y.n);
        assert_eq!(x.mu, y.mu);
        assert_eq!(x.alpha, y.alpha);
    }
    // Something actually happened in this scenario, in both paths.
    assert!(outcome.reallocations >= 1);
    for w in [0usize, 5] {
        assert!(outcome.suspected_dead.contains(&w), "worker {w}");
    }
    assert_eq!(outcome.post_setup_encodes, 0);
}

#[test]
fn adaptive_session_resolves_with_its_own_policy() {
    // A session built from a *policy* (not an explicit allocation) must
    // re-solve through that policy's `allocate_capped` when workers die:
    // here uniform-rate-0.5 — the re-solved allocation (n = 2k over the 8
    // survivors) fits the coded-row budget, so the re-allocation succeeds,
    // stays decodable, and never re-encodes. The legacy shim path (None
    // policy) is covered by serve_arrivals_adaptive_shim_matches above.
    let spec = small_spec();
    let (a, reqs) = data(14, 1008);
    let cfg = fast_cfg(0xF00F);
    let offsets: Vec<Duration> =
        (0..14).map(|i| Duration::from_millis(4 * i as u64)).collect();
    let scenario = FailureScenario::new(vec![FailureEvent {
        at_batch: 2,
        kind: FailureKind::KillWorkers(vec![0, 5]),
    }])
    .unwrap();
    let adapt = AdaptiveServeConfig {
        est: EstimatorConfig {
            min_obs: 1_000_000,
            check_every: 1,
            ..Default::default()
        },
        death_after: 3,
    };
    let outcome = Session::builder(&spec)
        .policy(policy::resolve("uniform-rate=0.5").unwrap())
        .data(a)
        .requests(reqs)
        .config(cfg)
        .scenario(scenario)
        .adaptive(adapt)
        .mode(Mode::Arrivals { offsets, max_batch: 1 })
        .build()
        .unwrap()
        .serve()
        .unwrap();
    assert_eq!(outcome.recorder.count(), 14);
    assert!(outcome.worst_error < 1e-8, "err {}", outcome.worst_error);
    assert!(outcome.reallocations >= 1, "re-solve through the policy failed");
    for w in [0usize, 5] {
        assert!(outcome.suspected_dead.contains(&w), "worker {w}");
    }
    assert_eq!(outcome.post_setup_encodes, 0);
    assert_eq!(outcome.encodes, 1);
}

#[test]
fn session_serve_is_deterministic_across_repeat_serves() {
    // One built session, served twice: all deterministic fields identical
    // (fresh wall clocks aside) — the facade owns no hidden mutable state.
    let spec = small_spec();
    let alloc = redundant_alloc(&spec);
    let (a, reqs) = data(4, 1007);
    let cfg = fast_cfg(0x9E9E);
    // t = 0 arrivals: deterministic (2, 2) batching on both serves.
    let offsets: Vec<Duration> = vec![Duration::ZERO; 4];
    let session = Session::builder(&spec)
        .allocation(alloc)
        .data(a)
        .requests(reqs)
        .config(cfg)
        .mode(Mode::Arrivals { offsets, max_batch: 2 })
        .build()
        .unwrap();
    let o1 = session.serve().unwrap();
    let o2 = session.serve().unwrap();
    assert_jobs_identical(&o1.jobs, &o2.jobs, "repeat serve");
    assert_eq!(o1.encodes, o2.encodes);
}

#[test]
fn every_cli_policy_name_resolves_to_exactly_one_policy() {
    // The registry is the single source of truth: every name is unique,
    // resolves, allocates on the paper cluster, and the parameterized
    // spellings resolve to the same policy as their flag-driven form.
    let names = policy::policy_names();
    assert!(names.contains(&"proposed"));
    assert!(names.contains(&"uncoded"));
    assert!(names.contains(&"uniform-nstar"));
    assert!(names.contains(&"uniform-rate"));
    assert!(names.contains(&"group-code"));
    assert!(names.contains(&"reisizadeh"));
    for (i, name) in names.iter().enumerate() {
        assert_eq!(
            names.iter().position(|n| n == name),
            Some(i),
            "duplicate registry name `{name}`"
        );
        let p = policy::resolve(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec = ClusterSpec::paper_two_group(10_000);
        let alloc = p
            .allocate(LatencyModel::A, &spec)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        alloc.validate(&spec).unwrap();
    }
    // Unknown names fail with the registry listing.
    let err = policy::resolve("nonexistent").unwrap_err().to_string();
    for name in &names {
        assert!(err.contains(name), "error should list `{name}`: {err}");
    }
}

#[test]
fn every_cli_code_name_resolves_to_exactly_one_code() {
    // The code registry mirrors the policy registry: unique names, each
    // resolving to a code whose setup succeeds on a serving-sized (n, k),
    // and unknown names list every known name.
    use hetcoded::coding::code;
    let names = code::code_names();
    assert!(names.contains(&"mds-random"));
    assert!(names.contains(&"mds-vandermonde"));
    assert!(names.contains(&"sparse-parity"));
    for (i, name) in names.iter().enumerate() {
        assert_eq!(
            names.iter().position(|n| n == name),
            Some(i),
            "duplicate code registry name `{name}`"
        );
        let c = code::resolve(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(c.name(), *name, "registry name / code name mismatch");
        let gen = c
            .setup(128, 64, 17)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(gen.matrix().rows(), 128);
        assert_eq!(gen.matrix().cols(), 64);
    }
    let err = code::resolve("nonexistent").unwrap_err().to_string();
    for name in &names {
        assert!(err.contains(name), "error should list `{name}`: {err}");
    }
}
