//! The headline failure/drift experiment (ISSUE 3 acceptance criterion):
//! under a mid-stream 2× slowdown of one group, the adaptive
//! re-allocation path's steady-state sojourn p99 must beat the static
//! allocation's by ≥ 2×, and re-allocation must never re-encode.
//!
//! Why the gap is structural, not a tuning artifact: the arrival rate is
//! placed between the post-drift saturation rates of the two policies —
//! the drifted cluster under the *static* allocation cannot sustain it
//! (`ρ > 1`, the queue diverges and sojourn grows linearly for the rest of
//! the run), while the re-solved allocation restores `ρ < 1` and a finite
//! steady state. Numerically (Monte-Carlo over the same spec): `E[S]`
//! pre-drift ≈ 0.084, static post-drift ≈ 0.141, re-solved post-drift
//! ≈ 0.103; at `λ = 8.2` that is `ρ` ≈ 0.69 → 1.15 (unstable) → 0.84.

use hetcoded::math::Summary;
use hetcoded::model::{ClusterSpec, EstimatorConfig, Group, LatencyModel};
use hetcoded::workload::{
    run_workload_drift, AdaptPolicy, ArrivalProcess, DriftEvent, DriftKind,
    DriftSchedule, DriftWorkloadConfig,
};

fn spec3() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 6, mu: 8.0, alpha: 1.0 },
            Group { n: 8, mu: 4.0, alpha: 1.0 },
            Group { n: 10, mu: 1.0, alpha: 1.0 },
        ],
        1000,
    )
    .unwrap()
}

#[test]
fn adaptive_beats_static_by_2x_p99_under_midstream_slowdown() {
    let spec = spec3();
    let jobs = 3_000usize;
    let rate = 8.2;
    // Mid-stream: the fastest group dilates 2× (α ← 2α, μ ← μ/2) halfway
    // through the expected arrival span.
    let drift_t = jobs as f64 / (2.0 * rate);
    let schedule = DriftSchedule::new(vec![DriftEvent {
        at: drift_t,
        kind: DriftKind::SlowGroup { group: 0, factor: 2.0 },
    }])
    .unwrap();
    let cfg = DriftWorkloadConfig {
        arrivals: ArrivalProcess::Poisson { rate },
        jobs,
        seed: 2019,
    };

    let static_run = run_workload_drift(
        &spec,
        LatencyModel::A,
        &cfg,
        &schedule,
        &AdaptPolicy::Static,
    )
    .unwrap();
    let adaptive_run = run_workload_drift(
        &spec,
        LatencyModel::A,
        &cfg,
        &schedule,
        &AdaptPolicy::Adaptive(EstimatorConfig::default()),
    )
    .unwrap();

    // The adaptive loop detected the drift and re-solved at least once
    // (detection + a refinement pass once the window holds only post-drift
    // observations are both acceptable).
    assert!(
        !adaptive_run.reallocations.is_empty(),
        "drift was never detected"
    );
    let first = &adaptive_run.reallocations[0];
    assert!(
        first.at >= drift_t,
        "re-allocated at t = {} before the drift at {drift_t}",
        first.at
    );
    // The last re-solve's estimate of the slowed group is in the right
    // regime: μ̂ clearly below the original 8.0.
    let last = adaptive_run.reallocations.last().unwrap();
    assert!(
        last.assumed.groups[0].mu < 6.0,
        "estimator missed the slowdown: μ̂ = {}",
        last.assumed.groups[0].mu
    );

    // Steady-state window: jobs arriving in the last 30% of the stream
    // (well past drift + detection + queue drain).
    let span = *static_run.arrivals.last().unwrap();
    let t0 = 0.7 * span;
    assert!(t0 > drift_t, "steady-state window must be post-drift");
    let p99_static = static_run.sojourn_percentile_after(t0, 99.0);
    let p99_adaptive = adaptive_run.sojourn_percentile_after(t0, 99.0);
    assert!(
        p99_static >= 2.0 * p99_adaptive,
        "acceptance: static p99 {p99_static:.3} must be >= 2x adaptive \
         p99 {p99_adaptive:.3} (got {:.1}x)",
        p99_static / p99_adaptive
    );

    // And the adaptive path genuinely recovered, not just "less awful":
    // its post-drift steady state stays within an order of magnitude of
    // the pre-drift scale, while static's diverged.
    let mut pre = Summary::keeping_samples();
    for i in 0..static_run.arrivals.len() {
        if static_run.arrivals[i] < 0.9 * drift_t {
            pre.add(static_run.finishes[i] - static_run.arrivals[i]);
        }
    }
    let pre_median = pre.percentile(50.0);
    assert!(
        p99_adaptive < 50.0 * pre_median,
        "adaptive did not re-stabilize: p99 {p99_adaptive:.3} vs pre-drift \
         median {pre_median:.4}"
    );
    assert!(
        p99_static > 10.0 * p99_adaptive,
        "expected an instability-sized gap, got static {p99_static:.3} vs \
         adaptive {p99_adaptive:.3}"
    );
}

#[test]
fn drift_experiment_is_deterministic() {
    let spec = spec3();
    let schedule = DriftSchedule::new(vec![DriftEvent {
        at: 20.0,
        kind: DriftKind::SlowGroup { group: 0, factor: 2.0 },
    }])
    .unwrap();
    let cfg = DriftWorkloadConfig {
        arrivals: ArrivalProcess::Poisson { rate: 6.0 },
        jobs: 600,
        seed: 7,
    };
    let a = run_workload_drift(
        &spec,
        LatencyModel::A,
        &cfg,
        &schedule,
        &AdaptPolicy::Adaptive(EstimatorConfig::default()),
    )
    .unwrap();
    let b = run_workload_drift(
        &spec,
        LatencyModel::A,
        &cfg,
        &schedule,
        &AdaptPolicy::Adaptive(EstimatorConfig::default()),
    )
    .unwrap();
    assert_eq!(a.finishes, b.finishes);
    assert_eq!(a.reallocations.len(), b.reallocations.len());
    for (x, y) in a.reallocations.iter().zip(&b.reallocations) {
        assert_eq!(x.job, y.job);
        assert_eq!(x.loads, y.loads);
    }
}

#[test]
fn tail_only_mu_drift_is_milder_than_dilation() {
    // ScaleGroupMu halves μ but keeps the shift; the same-magnitude
    // dilation (SlowGroup) also doubles the deterministic part, so its
    // post-drift service times dominate. Sanity for the two drift kinds.
    let spec = spec3();
    let cfg = DriftWorkloadConfig {
        arrivals: ArrivalProcess::Poisson { rate: 4.0 },
        jobs: 1_200,
        seed: 99,
    };
    let mid = 1_200.0 / 8.0;
    let mk = |kind| {
        DriftSchedule::new(vec![DriftEvent { at: mid, kind }]).unwrap()
    };
    let mu_only = run_workload_drift(
        &spec,
        LatencyModel::A,
        &cfg,
        &mk(DriftKind::ScaleGroupMu { group: 0, factor: 0.5 }),
        &AdaptPolicy::Static,
    )
    .unwrap();
    let dilated = run_workload_drift(
        &spec,
        LatencyModel::A,
        &cfg,
        &mk(DriftKind::SlowGroup { group: 0, factor: 2.0 }),
        &AdaptPolicy::Static,
    )
    .unwrap();
    let t0 = mid * 1.2;
    assert!(
        dilated.sojourn_after(t0).mean() > mu_only.sojourn_after(t0).mean(),
        "dilation must hurt at least as much as tail-only drift"
    );
}
