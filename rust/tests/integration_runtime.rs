//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have run; they are skipped (with a
//! loud message) when the artifacts directory is missing so `cargo test`
//! stays usable in a fresh checkout.

use hetcoded::coding::Matrix;
use hetcoded::math::Rng;
use hetcoded::runtime::Runtime;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/manifest.txt missing — run `make artifacts`");
        None
    }
}

#[test]
fn runtime_loads_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).expect("load artifacts");
    assert!(!rt.tile_rows().is_empty());
    assert_eq!(rt.cols(), 256);
    assert!(rt.max_tile_rows() >= 256);
    assert!(rt.encode_shape().is_some());
}

#[test]
fn matvec_exact_tile_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let d = rt.cols();
    let mut rng = Rng::new(1);
    for &rows in &rt.tile_rows() {
        let a = Matrix::from_fn(rows, d, |_, _| rng.normal());
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let got = rt.matvec(&a, &x).unwrap();
        let want = a.matvec(&x);
        let err = got
            .iter()
            .zip(&want)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f64, f64::max);
        // f32 artifact path vs f64 native: tolerance scales with d.
        assert!(err < 5e-3, "tile {rows}: err {err}");
    }
}

#[test]
fn matvec_pads_odd_sizes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let d = rt.cols();
    let mut rng = Rng::new(2);
    for rows in [1usize, 7, 63, 65, 100, 129, 255, 300] {
        let a = Matrix::from_fn(rows, d, |_, _| rng.normal());
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let got = rt.matvec(&a, &x).unwrap();
        assert_eq!(got.len(), rows, "rows={rows}");
        let want = a.matvec(&x);
        let err = got
            .iter()
            .zip(&want)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 5e-3, "rows {rows}: err {err}");
    }
}

#[test]
fn matvec_chunks_beyond_largest_tile() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let d = rt.cols();
    let rows = rt.max_tile_rows() * 2 + 37;
    let mut rng = Rng::new(3);
    let a = Matrix::from_fn(rows, d, |_, _| rng.normal());
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let got = rt.matvec(&a, &x).unwrap();
    assert_eq!(got.len(), rows);
    let want = a.matvec(&x);
    let err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f64, f64::max);
    assert!(err < 5e-3, "err {err}");
}

#[test]
fn matvec_rejects_wrong_width() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let a = Matrix::zeros(64, rt.cols() + 1);
    let x = vec![0.0; rt.cols() + 1];
    assert!(rt.matvec(&a, &x).is_err());
    let a2 = Matrix::zeros(64, rt.cols());
    let x2 = vec![0.0; rt.cols() - 1];
    assert!(rt.matvec(&a2, &x2).is_err());
}

#[test]
fn encode_matches_native_matmul() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let (n, k, d) = rt.encode_shape().unwrap();
    let mut rng = Rng::new(4);
    let g = Matrix::from_fn(n, k, |_, _| rng.normal() / (k as f64).sqrt());
    let a = Matrix::from_fn(k, d, |_, _| rng.normal());
    let got = rt.encode(&g, &a).unwrap();
    let want = g.matmul(&a);
    let mut err = 0.0f64;
    for i in 0..n {
        for j in 0..d {
            err = err.max((got[(i, j)] - want[(i, j)]).abs());
        }
    }
    assert!(err < 5e-3, "encode err {err}");
}

#[test]
fn encode_rejects_wrong_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let (n, k, d) = rt.encode_shape().unwrap();
    let g = Matrix::zeros(n - 1, k);
    let a = Matrix::zeros(k, d);
    assert!(rt.encode(&g, &a).is_err());
}

#[test]
fn batched_matvec_matches_per_vector_path() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let Some(bw) = rt.batch_width() else {
        panic!("batched artifacts missing from manifest");
    };
    let d = rt.cols();
    let mut rng = Rng::new(9);
    let rows = 100; // forces padding
    let a = Matrix::from_fn(rows, d, |_, _| rng.normal());
    let xs: Vec<Vec<f64>> = (0..bw.min(5))
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();
    let batched = rt.matvec_batched(&a, &xs).unwrap();
    assert_eq!(batched.len(), xs.len());
    for (b, x) in xs.iter().enumerate() {
        let single = rt.matvec(&a, x).unwrap();
        assert_eq!(batched[b].len(), rows);
        let err = batched[b]
            .iter()
            .zip(&single)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-4, "request {b}: batched vs single err {err}");
    }
}

#[test]
fn batched_matvec_rejects_oversized_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).unwrap();
    let bw = rt.batch_width().unwrap();
    let a = Matrix::zeros(64, rt.cols());
    let xs: Vec<Vec<f64>> = (0..bw + 1).map(|_| vec![0.0; rt.cols()]).collect();
    assert!(rt.matvec_batched(&a, &xs).is_err());
    assert!(rt.matvec_batched(&a, &[]).is_err());
}
