//! Integration tests for the prepared-job serving fast path (PR 2):
//!
//! - `serve_arrivals` on the prepared path produces a stream equivalent to
//!   replaying the same seeds through cold `run_job_batched` calls;
//! - steady-state serving performs zero encode/chunk work after the first
//!   batch;
//! - batched multi-RHS decode and the factorization-cached path agree with
//!   per-job decode on real encoded data;
//! - the cached repeated-pattern decode is at least 2× faster than
//!   refactorizing (the §Perf acceptance floor; the real ratio is ~k/3).
//!
//! Exercises the deprecated free-function shims on purpose: they must
//! keep reproducing their historical behaviour through the `Session`
//! facade (see also `session_parity.rs` for bit-identity).
#![allow(deprecated)]

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::{Decoder, Generator, GeneratorKind, Matrix};
use hetcoded::coordinator::{
    derive_stream_seed, run_job_batched, serve_arrivals, JobConfig,
    NativeCompute, PreparedJob,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use std::sync::Arc;
use hetcoded::runtime::wall_now;
use std::time::Duration;

fn spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

fn fast_cfg() -> JobConfig {
    JobConfig { time_scale: 0.002, ..Default::default() }
}

/// `serve_arrivals` (prepared path, one generator for the stream) must
/// replay the same straggle process as cold per-batch `run_job_batched`
/// calls with the same derived seeds: identical batching, worker usage,
/// row support, and model latency, with decodes agreeing on `A·x`.
#[test]
fn serve_arrivals_stream_matches_cold_replay() {
    let spec = spec();
    // n = 130 gives every worker exactly 13 rows, so the collect loop
    // always consumes 5 replies (65 rows ≥ k = 64) no matter which
    // near-simultaneous worker wakes first — the structural fields below
    // are scheduling-independent.
    let alloc = uniform_allocation(LatencyModel::A, &spec, 130.0).unwrap();
    let mut rng = Rng::new(81);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let requests: Vec<Vec<f64>> =
        (0..6).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
    // All requests queued at t=0 with max_batch 3: deterministically two
    // batches of three, whatever the wall clock does.
    let offsets = vec![Duration::ZERO; 6];
    let cfg = fast_cfg();
    let report = serve_arrivals(
        &spec,
        &alloc,
        &a,
        &requests,
        &offsets,
        3,
        Arc::new(NativeCompute),
        &cfg,
    )
    .unwrap();
    assert_eq!(report.jobs.len(), 6);
    assert_eq!(report.encodes, 1);
    assert!(report.worst_error < 1e-8, "err {}", report.worst_error);

    // Cold replay: one fresh (re-encoding) batched job per batch, seeded
    // exactly as the serving loop seeds batch 0 and batch 1.
    let mut cold_jobs = Vec::new();
    for batch in 0..2u64 {
        let mut jcfg = cfg.clone();
        jcfg.seed = derive_stream_seed(cfg.seed, batch);
        let lo = batch as usize * 3;
        let reports = run_job_batched(
            &spec,
            &alloc,
            &a,
            &requests[lo..lo + 3],
            Arc::new(NativeCompute),
            &jcfg,
        )
        .unwrap();
        cold_jobs.extend(reports);
    }
    assert_eq!(cold_jobs.len(), 6);
    for (i, (live, cold)) in report.jobs.iter().zip(&cold_jobs).enumerate() {
        // The straggle realization is seed-derived, so the stream's
        // structural fields match the cold replay bit for bit.
        assert_eq!(live.model_latency, cold.model_latency, "req {i}");
        assert_eq!(live.workers_used, cold.workers_used, "req {i}");
        assert_eq!(live.rows_collected, cold.rows_collected, "req {i}");
        assert_eq!(live.n, cold.n, "req {i}");
        // Both decode the same A·x; the cold path draws a fresh generator
        // per batch, so agreement is to decode tolerance, not bitwise.
        for (l, c) in live.decoded.iter().zip(&cold.decoded) {
            assert!((l - c).abs() < 1e-7, "req {i}: {l} vs {c}");
        }
    }
}

/// Batched + cached decode agrees with per-job decode on real encoded
/// data: encode, evaluate a fixed received support for a request batch,
/// then compare every path (bitwise where the code path is shared).
#[test]
fn batched_and_cached_decode_agree_with_per_job_decode() {
    for kind in [GeneratorKind::SystematicRandom, GeneratorKind::Vandermonde] {
        let (n, k, d, b) = (30usize, 16usize, 6usize, 4usize);
        let gen = Generator::new(kind, n, k, 17).unwrap();
        let mut rng = Rng::new(18);
        let a = Matrix::from_fn(k, d, |_, _| rng.normal());
        let coded = gen.matrix().matmul(&a);
        let requests: Vec<Vec<f64>> =
            (0..b).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        // A scrambled mixed support, as a straggle realization produces.
        let rows: Vec<usize> =
            vec![21, 3, 28, 10, 0, 17, 25, 7, 13, 29, 5, 19, 11, 23, 1, 15];
        assert_eq!(rows.len(), k);
        let columns: Vec<Vec<f64>> = requests
            .iter()
            .map(|x| {
                rows.iter()
                    .map(|&i| {
                        coded.row(i).iter().zip(x).map(|(c, xv)| c * xv).sum()
                    })
                    .collect()
            })
            .collect();
        let mut dec = Decoder::new(gen.clone());
        let batch = dec.decode_batch(&rows, &columns).unwrap();
        let (hits0, misses0) = dec.cache_stats();
        assert_eq!((hits0, misses0), (0, 1), "{kind:?}");
        let mut uncached = Decoder::with_cache_capacity(gen, 0);
        for (req, (col, got)) in requests.iter().zip(columns.iter().zip(&batch)) {
            let pairs: Vec<(usize, f64)> =
                rows.iter().copied().zip(col.iter().copied()).collect();
            // Cached single decode (hits the batch's factorization) and
            // uncached single decode agree with the batch bitwise.
            assert_eq!(got, &dec.decode(&pairs).unwrap(), "{kind:?}");
            assert_eq!(got, &uncached.decode(&pairs).unwrap(), "{kind:?}");
            // And everything decodes the right thing. The Vandermonde
            // interpolation on a scrambled node subset is ill-conditioned
            // relative to the random construction, hence the looser bar.
            let tol = match kind {
                GeneratorKind::SystematicRandom => 1e-8,
                GeneratorKind::Vandermonde => 1e-3,
            };
            let truth = a.matvec(req);
            let err = got
                .iter()
                .zip(&truth)
                .map(|(z, t)| (z - t).abs())
                .fold(0.0f64, f64::max);
            assert!(err < tol, "{kind:?}: err {err}");
        }
        let (hits, _) = dec.cache_stats();
        assert_eq!(hits, b as u64, "{kind:?}: singles should hit the cache");
    }
}

/// Steady-state prepared serving re-encodes nothing and the factorization
/// cache absorbs repeated straggler patterns across batches.
#[test]
fn prepared_serving_amortizes_encode_across_batches() {
    // k = 65 with 13 rows per worker and half the cluster dead: the five
    // live workers' 65 rows are *exactly* k, so every batch's decode
    // support is the full live row set — whatever order replies land in
    // and whichever worker straggles worst. The cache keys on the sorted
    // set, so every batch after the first is a guaranteed hit even though
    // each draws a fresh straggle realization. (With k < rows collected,
    // the first-k subset would depend on which worker arrived last and
    // the key would jitter per batch.)
    let spec = ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        65,
    )
    .unwrap();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 130.0).unwrap();
    let mut rng = Rng::new(91);
    let a = Matrix::from_fn(65, 8, |_, _| rng.normal());
    let mut cfg = fast_cfg();
    cfg.dead_workers = vec![0, 1, 2, 3, 4];
    let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
    for batch in 0..4u64 {
        let requests: Vec<Vec<f64>> =
            (0..3).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let reports = prepared
            .run_batch(&requests, Arc::new(NativeCompute), 100 + batch)
            .unwrap();
        assert!(reports.iter().all(|r| r.max_error < 1e-8), "batch {batch}");
        assert!(reports.iter().all(|r| r.rows_collected == 65), "batch {batch}");
    }
    assert_eq!(prepared.encode_count(), 1);
    let (hits, misses) = prepared.decode_cache_stats();
    assert_eq!(misses, 1, "one factorization for the repeated pattern");
    assert_eq!(hits, 3, "later batches reuse it");
}

/// The §Perf acceptance floor: decoding a repeated straggler pattern with
/// the factorization cache is at least 2× faster than refactorizing every
/// time. (The asymptotic ratio is ~k/3 — LU factor O(k³) vs solve O(k²) —
/// so 2× leaves a wide margin against CI noise.)
#[test]
fn cached_repeated_pattern_decode_is_at_least_2x_faster() {
    let (n, k) = (384usize, 256usize);
    let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 23).unwrap();
    let mut rng = Rng::new(24);
    let received: Vec<(usize, f64)> =
        (n - k..n).map(|i| (i, rng.normal())).collect();
    let mut cold = Decoder::with_cache_capacity(gen.clone(), 0);
    let mut warm = Decoder::new(gen);
    warm.decode(&received).unwrap(); // populate the cache
    let mut time = |dec: &mut Decoder| {
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t = wall_now();
            std::hint::black_box(dec.decode(&received).unwrap());
            best = best.min(t.elapsed().as_secs_f64());
        }
        best
    };
    let cold_best = time(&mut cold);
    let warm_best = time(&mut warm);
    assert!(
        warm_best * 2.0 <= cold_best,
        "cached {warm_best:.2e}s vs uncached {cold_best:.2e}s (< 2x)"
    );
}
