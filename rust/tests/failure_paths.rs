//! Failure-path integration tests: dead workers on the prepared serving
//! path, clean sub-`k` failures (no hangs), and the live adaptive loop
//! re-allocating under scripted scenarios without ever re-encoding.
//!
//! Exercises the deprecated `serve_arrivals_adaptive` shim on purpose: it
//! must keep reproducing its historical behaviour through the `Session`
//! facade (see also `session_parity.rs` for bit-identity).
#![allow(deprecated)]

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{
    serve_arrivals_adaptive, AdaptiveServeConfig, FailureEvent, FailureKind,
    FailureScenario, JobConfig, NativeCompute, PreparedJob,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, EstimatorConfig, Group, LatencyModel};
use std::sync::Arc;
use std::time::Duration;

fn small_spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

fn data(seed: u64, requests: usize) -> (Matrix, Vec<Vec<f64>>) {
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let reqs = (0..requests)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    (a, reqs)
}

fn fast_cfg() -> JobConfig {
    JobConfig { time_scale: 0.002, ..Default::default() }
}

#[test]
fn dead_workers_decode_bit_identically_and_correctly() {
    // Rate-1/2 code: surviving rows still cover k after two deaths. The
    // decode must (a) match ground truth and (b) be bit-identical across
    // repeat runs with the same seed — dead workers change *which* rows
    // arrive, never the decoded values' determinism.
    let spec = small_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let (a, reqs) = data(90, 3);
    let mut cfg = fast_cfg();
    cfg.dead_workers = vec![0, 5];
    let run = |cfg: &JobConfig| {
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, cfg).unwrap();
        prepared.run_batch(&reqs, Arc::new(NativeCompute), 77).unwrap()
    };
    let first = run(&cfg);
    let second = run(&cfg);
    assert_eq!(first.len(), 3);
    for (r1, r2) in first.iter().zip(&second) {
        assert!(r1.max_error < 1e-8, "err {}", r1.max_error);
        assert_eq!(r1.decoded, r2.decoded, "decode must be deterministic");
        assert!(r1.rows_collected >= 64);
    }
    // The dead workers' rows never arrive: with per-worker loads of ~13
    // rows, 8 alive workers bound the collectible support.
    let alive_rows: usize = {
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        prepared
            .per_worker()
            .iter()
            .enumerate()
            .filter(|(w, _)| !cfg.dead_workers.contains(w))
            .map(|(_, &l)| l)
            .sum()
    };
    assert!(first.iter().all(|r| r.rows_collected <= alive_rows));

    // And the alive-only decode agrees with the no-deaths decode on the
    // same requests (both equal A·x to numerical precision).
    let baseline = run(&fast_cfg());
    for (r_dead, r_alive) in first.iter().zip(&baseline) {
        for (x, y) in r_dead.decoded.iter().zip(&r_alive.decoded) {
            assert!((x - y).abs() < 1e-7, "{x} vs {y}");
        }
    }
}

#[test]
fn sub_k_survivors_error_instead_of_hanging() {
    // Kill so many workers that k rows can never arrive: run_batch must
    // return a decode error promptly (the reply channel closes once every
    // live worker has reported), not block forever.
    let spec = small_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let (a, reqs) = data(91, 2);
    let mut cfg = fast_cfg();
    cfg.dead_workers = (0..9).collect(); // one survivor: ~13 rows < 64
    let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
    let started = hetcoded::runtime::wall_now();
    let res = prepared.run_batch(&reqs, Arc::new(NativeCompute), 5);
    assert!(res.is_err(), "sub-k survivors must fail");
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "failure path took {:?} — looks like a hang",
        started.elapsed()
    );
    let msg = format!("{}", res.unwrap_err());
    assert!(msg.contains("rows arrived"), "unexpected error: {msg}");
}

#[test]
fn live_adaptive_loop_detects_group_slowdown_and_never_reencodes() {
    // A 2x dilation of the fast group mid-stream on the *live* threaded
    // path: the estimator sees the consumed replies drift, re-solves, and
    // re-chunks — with the measured encode counter pinned at the single
    // setup encode (ServeReport.encodes == 1, post_setup_encodes == 0).
    //
    // The code is deliberately tight (n = 80 over k = 64, 8 of 10 workers
    // needed) so the slowed group keeps being consumed post-drift — a
    // high-redundancy code could serve entirely from the healthy group and
    // starve the estimator of the very observations that show the drift.
    let spec = small_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 80.0).unwrap();
    let (a, reqs) = data(92, 56);
    let offsets: Vec<Duration> =
        (0..56).map(|i| Duration::from_millis(3 * i as u64)).collect();
    let cfg = fast_cfg();
    let scenario = FailureScenario::new(vec![FailureEvent {
        at_batch: 3,
        kind: FailureKind::SlowGroup { group: 0, factor: 2.0 },
    }])
    .unwrap();
    let adapt = AdaptiveServeConfig {
        est: EstimatorConfig {
            // A short window so the pre-drift records age out within a
            // fraction of the stream: once the window is all post-drift,
            // the α̂ trigger (the dilated shift doubles the observed
            // minimum) fires deterministically, independent of μ̂ noise
            // and its significance floor.
            window: 16,
            // 16 pooled observations gate estimates past the ~3 pre-drift
            // batches (too few samples to trust), so detection fires on
            // post-drift data rather than warm-up noise.
            min_obs: 16,
            threshold: 0.25,
            check_every: 1,
        },
        death_after: 1_000, // drift-only: keep the death detector out
    };
    let rep = serve_arrivals_adaptive(
        &spec,
        &alloc,
        &a,
        &reqs,
        &offsets,
        2,
        Arc::new(NativeCompute),
        &cfg,
        &scenario,
        Some(&adapt),
    )
    .unwrap();
    assert_eq!(rep.serve.recorder.count(), 56);
    assert!(rep.serve.worst_error < 1e-8, "err {}", rep.serve.worst_error);
    assert!(
        rep.reallocations >= 1,
        "live estimator never detected the slowdown"
    );
    assert!(rep.suspected_dead.is_empty());
    // Acceptance: re-allocation re-slices cached coded rows — zero encode
    // passes after setup, measured (the encoder's own counter), for the
    // whole adaptive stream.
    assert_eq!(rep.post_setup_encodes, 0);
    assert_eq!(rep.serve.encodes, 1);
    assert_eq!(rep.rechunks, rep.reallocations);
    // The believed spec moved toward the dilated truth (μ₀ fell).
    assert!(
        rep.assumed_spec.groups[0].mu < spec.groups[0].mu,
        "assumed μ₀ {} did not move below {}",
        rep.assumed_spec.groups[0].mu,
        spec.groups[0].mu
    );
}

#[test]
fn live_scenario_deaths_within_redundancy_keep_serving_without_adaptation() {
    // Even with adaptation off, scripted deaths inside the code's
    // redundancy budget must not break the stream (the MDS code absorbs
    // them); only the straggle realizations change.
    let spec = small_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let (a, reqs) = data(93, 8);
    let offsets: Vec<Duration> =
        (0..8).map(|i| Duration::from_millis(4 * i as u64)).collect();
    let scenario = FailureScenario::new(vec![FailureEvent {
        at_batch: 2,
        kind: FailureKind::KillWorkers(vec![1, 6]),
    }])
    .unwrap();
    let rep = serve_arrivals_adaptive(
        &spec,
        &alloc,
        &a,
        &reqs,
        &offsets,
        4,
        Arc::new(NativeCompute),
        &fast_cfg(),
        &scenario,
        None,
    )
    .unwrap();
    assert_eq!(rep.serve.recorder.count(), 8);
    assert!(rep.serve.worst_error < 1e-8);
    assert_eq!(rep.reallocations, 0);
    assert_eq!(rep.serve.encodes, 1);
}
