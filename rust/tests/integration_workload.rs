//! Integration tests: the workload layer end-to-end — policy sweep over
//! arrival rates on the paper's 2-group cluster (the `workload` CLI
//! scenario), plus the live batched serving loop on the thread coordinator.
//!
//! Exercises the deprecated `serve_arrivals` shim on purpose: it must
//! keep reproducing its historical behaviour through the `Session`
//! facade (see also `session_parity.rs` for bit-identity).
#![allow(deprecated)]

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{serve_arrivals, JobConfig, NativeCompute};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, LatencyModel};
use hetcoded::sim::Scheme;
use hetcoded::workload::{
    mean_service, run_workload, service_sampler, ArrivalProcess, WorkloadConfig,
};
use std::sync::Arc;
use std::time::Duration;

/// The acceptance scenario: two policies × three arrival rates on the
/// 2-group heterogeneous cluster, deterministic under a fixed seed.
#[test]
fn two_group_policy_sweep_under_load() {
    let spec = ClusterSpec::paper_two_group(10_000);
    let model = LatencyModel::A;
    for scheme in [Scheme::Proposed, Scheme::UniformWithOptimalN] {
        let (_, mut sampler) = service_sampler(&spec, scheme, model).unwrap();
        let es = mean_service(&mut sampler, 2_000, 2019 ^ 0xCA11B);
        assert!(es > 0.0 && es.is_finite());
        let mut last_p99 = 0.0;
        for rho in [0.3, 0.6, 0.9] {
            let cfg = WorkloadConfig {
                arrivals: ArrivalProcess::Poisson { rate: rho / es },
                jobs: 1_500,
                servers: 1,
                seed: 2019,
            };
            let rep = run_workload(&spec, scheme, model, &cfg).unwrap();
            let rep2 = run_workload(&spec, scheme, model, &cfg).unwrap();
            // Bit-reproducible under the fixed seed.
            assert_eq!(rep.makespan, rep2.makespan);
            assert_eq!(rep.sojourn.mean(), rep2.sojourn.mean());
            // Lossless queue, sane metrics.
            assert_eq!(rep.jobs, 1_500);
            assert!(rep.throughput > 0.0);
            assert!(rep.utilization > 0.0 && rep.utilization <= 1.0 + 1e-12);
            let (p50, p95, p99) = (
                rep.sojourn_percentile(50.0),
                rep.sojourn_percentile(95.0),
                rep.sojourn_percentile(99.0),
            );
            assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
            // The sojourn tail grows with offered load.
            assert!(p99 >= last_p99);
            last_p99 = p99;
            // Utilization tracks ρ while the queue is stable.
            if rho < 0.95 {
                assert!(
                    (rep.utilization - rho).abs() < 0.10,
                    "rho {rho}: util {}",
                    rep.utilization
                );
            }
        }
    }
}

/// The proposed policy sustains a higher arrival rate than uniform before
/// saturating: at a rate near uniform's saturation point, uniform's queue
/// explodes while proposed stays stable.
#[test]
fn proposed_sustains_more_traffic_than_uniform() {
    let spec = ClusterSpec::paper_two_group(10_000);
    let model = LatencyModel::A;
    let (_, mut su) =
        service_sampler(&spec, Scheme::UniformWithOptimalN, model).unwrap();
    let es_uniform = mean_service(&mut su, 2_000, 5);
    // Offered rate = 1.2 / E[S_uniform]: overloads uniform, and (because
    // proposed's E[S] is meaningfully smaller on this cluster) leaves the
    // proposed policy with spare capacity.
    let cfg = WorkloadConfig {
        arrivals: ArrivalProcess::Poisson { rate: 1.2 / es_uniform },
        jobs: 2_000,
        servers: 1,
        seed: 11,
    };
    let p = run_workload(&spec, Scheme::Proposed, model, &cfg).unwrap();
    let u =
        run_workload(&spec, Scheme::UniformWithOptimalN, model, &cfg).unwrap();
    assert!(
        p.sojourn.mean() < u.sojourn.mean(),
        "proposed sojourn {} !< uniform {}",
        p.sojourn.mean(),
        u.sojourn.mean()
    );
    assert!(
        p.max_in_system <= u.max_in_system,
        "proposed peak queue {} !<= uniform {}",
        p.max_in_system,
        u.max_in_system
    );
}

/// Bursty ON/OFF traffic at the same mean rate produces a heavier sojourn
/// tail than Poisson — the reason the workload layer models burstiness.
#[test]
fn bursty_traffic_has_heavier_tail() {
    let spec = ClusterSpec::paper_two_group(10_000);
    let model = LatencyModel::A;
    let (_, mut sampler) = service_sampler(&spec, Scheme::Proposed, model).unwrap();
    let es = mean_service(&mut sampler, 2_000, 5);
    let rate = 0.6 / es;
    let poisson = WorkloadConfig {
        arrivals: ArrivalProcess::Poisson { rate },
        jobs: 2_000,
        servers: 1,
        seed: 21,
    };
    let bursty = WorkloadConfig {
        arrivals: ArrivalProcess::OnOff {
            rate_on: 2.0 * rate,
            mean_on: 20.0 * es,
            mean_off: 20.0 * es,
        },
        ..poisson
    };
    assert!((bursty.arrivals.mean_rate() - rate).abs() < 1e-9);
    let p = run_workload(&spec, Scheme::Proposed, model, &poisson).unwrap();
    let b = run_workload(&spec, Scheme::Proposed, model, &bursty).unwrap();
    assert!(
        b.sojourn_percentile(99.0) > p.sojourn_percentile(99.0),
        "bursty p99 {} !> poisson p99 {}",
        b.sojourn_percentile(99.0),
        p.sojourn_percentile(99.0)
    );
}

/// The live coordinator path: replay a Poisson arrival trace against real
/// worker threads with batched dispatch; every request decodes exactly.
#[test]
fn live_serve_arrivals_end_to_end() {
    let spec = ClusterSpec::new(
        vec![
            hetcoded::model::Group { n: 4, mu: 8.0, alpha: 1.0 },
            hetcoded::model::Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let mut rng = Rng::new(31);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let requests: Vec<Vec<f64>> =
        (0..10).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
    let mut arrival_rng = Rng::new(32);
    let offsets: Vec<Duration> = ArrivalProcess::Poisson { rate: 400.0 }
        .times(10, &mut arrival_rng)
        .unwrap()
        .into_iter()
        .map(Duration::from_secs_f64)
        .collect();
    let cfg = JobConfig { time_scale: 0.002, ..Default::default() };
    let report = serve_arrivals(
        &spec,
        &alloc,
        &a,
        &requests,
        &offsets,
        4,
        Arc::new(NativeCompute),
        &cfg,
    )
    .unwrap();
    assert_eq!(report.recorder.count(), 10);
    assert_eq!(report.jobs.len(), 10);
    assert!(report.worst_error < 1e-8, "err {}", report.worst_error);
    assert!(report.makespan.is_some());
    for job in &report.jobs {
        assert_eq!(job.decoded.len(), 64);
    }
}
