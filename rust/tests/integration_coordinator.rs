//! Integration tests: the full coordinator over both compute backends,
//! including failure injection and batched serving.
//!
//! Exercises the deprecated free-function shims on purpose: they must
//! keep reproducing their historical behaviour through the `Session`
//! facade (see also `session_parity.rs` for bit-identity).
#![allow(deprecated)]

use hetcoded::allocation::{proposed_allocation, uniform_allocation};
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{run_job, serve_requests, JobConfig, NativeCompute};
#[cfg(feature = "xla")]
use hetcoded::coordinator::XlaService;
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
#[cfg(feature = "xla")]
use std::path::Path;
use std::sync::Arc;

fn spec(k: usize) -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 5, mu: 8.0, alpha: 1.0 },
            Group { n: 7, mu: 4.0, alpha: 1.0 },
            Group { n: 8, mu: 1.0, alpha: 1.0 },
        ],
        k,
    )
    .unwrap()
}

fn data(k: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(k, d, |_, _| rng.normal());
    let x = (0..d).map(|_| rng.normal()).collect();
    (a, x)
}

fn fast_cfg() -> JobConfig {
    JobConfig { time_scale: 0.002, ..Default::default() }
}

#[test]
fn native_end_to_end_proposed() {
    let spec = spec(128);
    let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
    let (a, x) = data(128, 32, 1);
    let r = run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &fast_cfg())
        .unwrap();
    assert!(r.max_error < 1e-8, "err {}", r.max_error);
    assert!(r.rows_collected >= 128);
}

#[test]
fn native_end_to_end_model_b() {
    let spec = spec(128);
    let alloc = proposed_allocation(LatencyModel::B, &spec).unwrap();
    let (a, x) = data(128, 32, 2);
    let mut cfg = fast_cfg();
    cfg.model = LatencyModel::B;
    cfg.time_scale = 2e-5; // model-B delays scale with absolute rows
    let r = run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &cfg).unwrap();
    assert!(r.max_error < 1e-8);
}

#[test]
fn failure_injection_up_to_redundancy() {
    let spec = spec(100);
    // Rate-1/2 code: half the workers can die.
    let alloc = uniform_allocation(LatencyModel::A, &spec, 200.0).unwrap();
    let (a, x) = data(100, 16, 3);
    for dead in [vec![0], vec![0, 7, 13], vec![1, 2, 3, 4, 5]] {
        let mut cfg = fast_cfg();
        cfg.dead_workers = dead.clone();
        let r = run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &cfg)
            .unwrap_or_else(|e| panic!("dead={dead:?}: {e}"));
        assert!(r.max_error < 1e-8, "dead={dead:?}");
    }
}

#[test]
fn overload_of_dead_workers_fails_cleanly() {
    let spec = spec(100);
    let alloc = uniform_allocation(LatencyModel::A, &spec, 120.0).unwrap();
    let (a, x) = data(100, 16, 4);
    let mut cfg = fast_cfg();
    cfg.dead_workers = (0..10).collect(); // kill half the cluster, rate 0.83
    let res = run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &cfg);
    assert!(res.is_err());
}

#[test]
fn serving_loop_has_stable_percentiles() {
    let spec = spec(96);
    let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
    let (a, _) = data(96, 16, 5);
    let mut rng = Rng::new(6);
    let reqs: Vec<Vec<f64>> =
        (0..12).map(|_| (0..16).map(|_| rng.normal()).collect()).collect();
    let report = serve_requests(
        &spec,
        &alloc,
        &a,
        &reqs,
        Arc::new(NativeCompute),
        &fast_cfg(),
    )
    .unwrap();
    assert_eq!(report.recorder.count(), 12);
    assert!(report.worst_error < 1e-8);
    assert!(report.recorder.percentile(95.0) >= report.recorder.percentile(50.0));
    assert!(report.recorder.rows_per_cpu_second() > 0.0);
    assert!(report.recorder.rows_per_wall_second() > 0.0);
}

#[cfg(feature = "xla")]
#[test]
fn xla_backend_end_to_end() {
    // Requires artifacts; skip cleanly otherwise.
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let svc = match XlaService::new("artifacts".into()) {
        Ok(s) => Arc::new(s),
        Err(e) => panic!("artifact load failed: {e}"),
    };
    let k = 256;
    let d = svc.cols();
    let spec = spec(k);
    let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
    let (a, x) = data(k, d, 7);
    let r = run_job(&spec, &alloc, &a, &x, svc, &fast_cfg()).unwrap();
    // f32 artifact numerics.
    assert!(r.max_error < 1e-2, "err {}", r.max_error);
    assert_eq!(r.decoded.len(), k);
    assert_eq!(r.backend, "xla-pjrt");
}

#[cfg(feature = "xla")]
#[test]
fn xla_and_native_agree() {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let svc = Arc::new(XlaService::new("artifacts".into()).unwrap());
    let k = 128;
    let d = svc.cols();
    let spec = spec(k);
    let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
    let (a, x) = data(k, d, 8);
    let cfg = fast_cfg();
    let rx = run_job(&spec, &alloc, &a, &x, svc, &cfg).unwrap();
    let rn = run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &cfg).unwrap();
    // Same seed => same straggle pattern => same decode support; results
    // agree to f32 tolerance.
    let err = rx
        .decoded
        .iter()
        .zip(&rn.decoded)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(err < 1e-2, "backend disagreement {err}");
}

#[cfg(feature = "xla")]
#[test]
fn xla_batched_job_end_to_end() {
    // Full batched path: one worker dispatch serves 4 requests through the
    // AOT batched matvec artifact; every request decodes correctly.
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let svc = Arc::new(XlaService::new("artifacts".into()).unwrap());
    let k = 256;
    let d = svc.cols();
    let spec = spec(k);
    let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
    let mut rng = Rng::new(12);
    let a = Matrix::from_fn(k, d, |_, _| rng.normal());
    let requests: Vec<Vec<f64>> =
        (0..4).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
    let reports = hetcoded::coordinator::run_job_batched(
        &spec,
        &alloc,
        &a,
        &requests,
        svc,
        &fast_cfg(),
    )
    .unwrap();
    assert_eq!(reports.len(), 4);
    for (i, r) in reports.iter().enumerate() {
        assert!(r.max_error < 1e-2, "request {i}: err {}", r.max_error);
        assert_eq!(r.backend, "xla-pjrt");
    }
}
