//! The rateless-serving headline suite: what the fountain buys over a
//! fixed-`n` code, measured — not declared — through the public
//! `Session` / `PreparedJob` surface.
//!
//! Two claims are pinned:
//!
//! 1. **Loss tolerance with bounded overhead.** Under a drop script that
//!    blacks out the redundancy-carrying group and Bernoulli-drops 10% of
//!    the remaining packets, `rateless-rlc` completes *every* job and the
//!    measured overhead (rows received ÷ k, per batch) stays ≤ 1.25×k —
//!    the round-inflation arithmetic guarantees ≤ (9/8)·k + 5 rows
//!    deterministically. The MDS code under the *same* script fails
//!    sub-k: its `n` rows are all that exist, and the surviving links
//!    cannot carry k of them.
//! 2. **Elastic scale-out with zero re-encodes.** Growing the chunking
//!    past the setup `n` mints fresh rows only
//!    ([`Encoder::re_encoded_rows`] stays 0, encode passes stay 1), and
//!    the scaled run is bit-reproducible from the seed at any pool size.
//!
//! [`Encoder::re_encoded_rows`]: hetcoded::coding::Encoder::re_encoded_rows

use hetcoded::allocation::uniform_allocation;
use hetcoded::coding::Matrix;
use hetcoded::coordinator::failures::{
    FailureEvent, FailureKind, FailureScenario,
};
use hetcoded::coordinator::{
    JobConfig, Mode, NativeCompute, PreparedJob, ServeOutcome, Session,
};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use std::sync::Arc;
use std::time::Duration;

fn two_group_spec() -> ClusterSpec {
    ClusterSpec::new(
        vec![
            Group { n: 4, mu: 8.0, alpha: 1.0 },
            Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )
    .unwrap()
}

fn workload(jobs: usize, seed: u64) -> (Matrix, Vec<Vec<f64>>, Vec<Duration>) {
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
    let reqs: Vec<Vec<f64>> = (0..jobs)
        .map(|_| (0..8).map(|_| rng.normal()).collect())
        .collect();
    let offsets = (0..jobs)
        .map(|i| Duration::from_millis(4 * i as u64))
        .collect();
    (a, reqs, offsets)
}

/// The shared drop script: from batch 0, group 1 (six workers carrying
/// ~76 of the 128 coded rows — more than the n − k = 64 redundancy) goes
/// completely dark, and group 0's links drop packets i.i.d. at 10%.
fn drop_script() -> FailureScenario {
    FailureScenario::new(vec![
        FailureEvent {
            at_batch: 0,
            kind: FailureKind::BurstDrop { group: 1, batches: 1_000 },
        },
        FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyGroup { group: 0, p: 0.1 },
        },
    ])
    .unwrap()
}

fn serve_with_code(code: &str, seed: u64) -> hetcoded::Result<ServeOutcome> {
    let spec = two_group_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0)?;
    let (a, reqs, offsets) = workload(6, 0xBEAD ^ seed);
    let cfg = JobConfig { time_scale: 0.002, seed, ..Default::default() };
    Session::builder(&spec)
        .allocation(alloc)
        .code(code)
        .data(a)
        .requests(reqs)
        .config(cfg)
        .compute(Arc::new(NativeCompute))
        .scenario(drop_script())
        .mode(Mode::Arrivals { offsets, max_batch: 2 })
        .build()?
        .serve()
}

#[test]
fn rateless_completes_under_loss_within_the_overhead_budget() {
    let outcome = serve_with_code("rateless-rlc", 11).expect(
        "the fountain must ride out the drop script the MDS code cannot",
    );
    assert_eq!(outcome.recorder.count(), 6, "every job completes");
    assert!(
        outcome.worst_error < 1e-6,
        "decodes stay exact: {}",
        outcome.worst_error
    );
    let rl = outcome.rateless.expect("rateless serving reports its summary");
    assert!(rl.batches >= 3, "6 jobs at max_batch 2: {} batches", rl.batches);
    // The headline number: measured rows-over-k, hard-bounded by the
    // issuance inflation (deficit + ceil(deficit/8) + packet), never a
    // declared constant.
    assert!(
        rl.overhead <= 1.25,
        "overhead {} blew the 1.25x budget",
        rl.overhead
    );
    assert!(rl.overhead >= 1.0, "overhead {} below 1 is a miscount", rl.overhead);
    assert!(rl.rows_received >= rl.batches * 64);
    assert!(rl.rows_issued >= rl.rows_received);
    // Loss is served by minting fresh rows, never by re-encoding old ones.
    assert_eq!(rl.re_encoded_rows, 0);
    assert_eq!(outcome.encodes, 1);
    assert_eq!(outcome.post_setup_encodes, 0);
}

#[test]
fn fixed_n_mds_fails_sub_k_under_the_same_drop_script() {
    let err = match serve_with_code("mds-random", 11) {
        Err(e) => e.to_string(),
        Ok(outcome) => panic!(
            "128 fixed rows minus group 1's ~76 cannot cover k = 64, yet \
             the MDS serve returned {} jobs",
            outcome.recorder.count()
        ),
    };
    assert!(
        err.contains("cannot solicit"),
        "expected the sub-k lossy-collection error, got: {err}"
    );
}

#[test]
fn scale_out_past_n_re_encodes_nothing_and_reproduces_at_any_pool_size() {
    let spec = two_group_spec();
    let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
    let (a, reqs, _) = workload(3, 0xE1A5);

    let mut runs: Vec<(usize, Vec<Vec<u64>>, Vec<Vec<u64>>)> = Vec::new();
    for threads in [1usize, 2, 7, 16] {
        let cfg = JobConfig {
            time_scale: 0.002,
            seed: 23,
            code: Some("rateless-rlc".into()),
            encode_threads: threads,
            ..Default::default()
        };
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let n0 = prepared.n();
        let (before, _) = prepared
            .run_batch_streamed(&reqs, Arc::new(NativeCompute), 5, &[])
            .unwrap();

        // Scale out: every worker gains three rows, pushing the chunking
        // past the setup horizon. A finite code would need a re-encode
        // (its `rechunk` refuses outright); the fountain mints the tail.
        let grown: Vec<usize> =
            prepared.per_worker().iter().map(|&l| l + 3).collect();
        let total: usize = grown.iter().sum();
        assert!(total > n0, "scale-out must exceed the setup horizon");
        prepared.extend_rechunk(&grown).unwrap();
        assert_eq!(prepared.n(), total, "horizon grew to the new chunking");
        let (after, _) = prepared
            .run_batch_streamed(&reqs, Arc::new(NativeCompute), 6, &[])
            .unwrap();

        // Measured, not declared: the scale-out minted rows [n0, total)
        // exactly once and re-encoded none of [0, n0).
        assert_eq!(prepared.re_encoded_rows(), 0);
        assert_eq!(prepared.encode_count(), 1);
        for r in before.iter().chain(&after) {
            assert!(r.max_error < 1e-6, "err {}", r.max_error);
        }
        let bits = |reports: &[hetcoded::coordinator::JobReport]| {
            reports
                .iter()
                .map(|r| r.decoded.iter().map(|v| v.to_bits()).collect())
                .collect::<Vec<Vec<u64>>>()
        };
        runs.push((threads, bits(&before), bits(&after)));
    }
    // Bit-reproducible from the seed at every pool size, before and
    // after the scale-out.
    let (_, ref_before, ref_after) = &runs[0];
    for (threads, before, after) in &runs[1..] {
        assert_eq!(before, ref_before, "pre-scale-out forked at pool={threads}");
        assert_eq!(after, ref_after, "post-scale-out forked at pool={threads}");
    }
}
