//! Paper-level integration checks: the headline quantitative claims of §IV
//! at reduced (but statistically sufficient) sample counts.

use hetcoded::allocation::optimal_latency_bound;
use hetcoded::figures::{self, FigureOpts};
use hetcoded::model::{ClusterSpec, LatencyModel};
use hetcoded::sim::{simulate_scheme, Scheme, SimConfig};

fn cfg() -> SimConfig {
    SimConfig { samples: 4_000, seed: 99, threads: 0 }
}

#[test]
fn headline_proposed_achieves_lower_bound() {
    // "the proposed load allocation method achieves the lower bound T*".
    let spec = ClusterSpec::paper_five_group(2500, 10_000);
    let r = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
    let t_star = optimal_latency_bound(LatencyModel::A, &spec);
    let gap = (r.mean - t_star) / t_star;
    assert!(gap > -0.01, "MC below the lower bound: gap {gap}");
    assert!(gap < 0.08, "does not achieve the bound: gap {gap}");
}

#[test]
fn headline_10x_over_group_code_at_large_n() {
    // "a 10x or more performance gain over the MDS code with fixed r ...
    //  as N increases".
    let spec = ClusterSpec::paper_five_group(20_000, 10_000);
    let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
    // The group-code latency floors at 1/r = 0.01.
    let gain = 0.01 / p.mean;
    assert!(gain > 10.0, "gain {gain} < 10x at N=20000");
}

#[test]
fn headline_18pct_over_uniform_nstar() {
    // "the proposed load allocation method has a 18% lower latency than the
    //  uniform load allocation does" (Fig. 4 operating point).
    let spec = ClusterSpec::paper_five_group(2500, 10_000);
    let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
    let u = simulate_scheme(&spec, Scheme::UniformWithOptimalN, LatencyModel::A, &cfg())
        .unwrap();
    let gain = (u.mean - p.mean) / u.mean;
    assert!(
        (0.08..0.35).contains(&gain),
        "gain over uniform(n*) = {gain} (paper: ~0.18)"
    );
}

#[test]
fn headline_fig8_best_uniform_rate_and_10pct() {
    // Fig. 8: best uniform rate near 0.52; proposed ~10% below it.
    let mut opts = FigureOpts::quick();
    opts.samples = 3_000;
    opts.points = 12;
    let fig = figures::generate(8, &opts).unwrap();
    let (best_rate, best_lat) = figures::fig8::best_uniform_rate(&fig);
    assert!(
        (0.42..0.62).contains(&best_rate),
        "best uniform rate {best_rate}, paper: 0.52"
    );
    let prop = fig.series[1].points[0].1;
    let gain = (best_lat - prop) / best_lat;
    assert!(
        (0.03..0.25).contains(&gain),
        "proposed gain {gain}, paper: ~0.10"
    );
}

#[test]
fn headline_model_b_consistent_with_reisizadeh() {
    // Fig. 9: both model-B schemes achieve T*_b.
    let spec = ClusterSpec::paper_three_group_b(2000, 100_000);
    let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::B, &cfg()).unwrap();
    let z = simulate_scheme(&spec, Scheme::Reisizadeh, LatencyModel::B, &cfg()).unwrap();
    let t = optimal_latency_bound(LatencyModel::B, &spec);
    assert!((p.mean - t) / t < 0.10, "proposed gap {}", (p.mean - t) / t);
    assert!((z.mean - t) / t < 0.10, "[32] gap {}", (z.mean - t) / t);
}

#[test]
fn integer_rounding_is_negligible() {
    // §III-B: "the round function on the optimal load allocation has a
    // negligible effect on the performance" — stated for practical k
    // (hundreds of thousands to millions of rows, i.e. per-worker loads in
    // the hundreds). Verify at k = 10^5 (loads ~40-65 rows) and also record
    // that the effect is visibly larger at small k where loads are ~4 rows.
    use hetcoded::allocation::proposed_allocation;
    use hetcoded::sim::latency_any_k;
    let rel_shift = |k: usize| {
        let spec = ClusterSpec::paper_five_group(2500, k);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let real = latency_any_k(&spec, &a.loads, LatencyModel::A, &cfg()).unwrap();
        let int_loads: Vec<f64> =
            a.integer_loads().iter().map(|&l| l as f64).collect();
        let rounded =
            latency_any_k(&spec, &int_loads, LatencyModel::A, &cfg()).unwrap();
        (rounded.mean() - real.mean()).abs() / real.mean()
    };
    let big_k = rel_shift(100_000);
    assert!(big_k < 0.01, "rounding at k=1e5 changed latency by {big_k}");
    let small_k = rel_shift(10_000);
    assert!(
        small_k > big_k,
        "rounding effect should shrink with k ({small_k} vs {big_k})"
    );
}

#[test]
fn clustering_extension_near_oracle() {
    // Footnote 1: k-means grouping of a fully heterogeneous fleet loses
    // almost nothing vs knowing the true groups.
    use hetcoded::allocation::proposed_allocation;
    use hetcoded::math::Rng;
    use hetcoded::model::clustering::{cluster_workers, WorkerParams};
    use hetcoded::model::Group;
    use hetcoded::sim::latency_any_k;
    let tiers = [(100usize, 12.0, 1.0), (150, 4.0, 1.0), (150, 1.0, 1.4)];
    let mut rng = Rng::new(17);
    let fleet: Vec<WorkerParams> = tiers
        .iter()
        .flat_map(|&(n, mu, alpha)| {
            (0..n)
                .map(|_| WorkerParams {
                    mu: mu * (1.0 + 0.1 * (rng.next_f64() - 0.5)),
                    alpha: alpha * (1.0 + 0.1 * (rng.next_f64() - 0.5)),
                })
                .collect::<Vec<_>>()
        })
        .collect();
    let (groups, _) = cluster_workers(&fleet, 3, 3).unwrap();
    let clustered = ClusterSpec::new(groups, 10_000).unwrap();
    let oracle = ClusterSpec::new(
        tiers.iter().map(|&(n, mu, alpha)| Group { n, mu, alpha }).collect(),
        10_000,
    )
    .unwrap();
    let ca = proposed_allocation(LatencyModel::A, &clustered).unwrap();
    let oa = proposed_allocation(LatencyModel::A, &oracle).unwrap();
    // Evaluate both on their own models (centroids are close, so this is a
    // fair proxy); latencies should agree within a few percent.
    let lc = latency_any_k(&clustered, &ca.loads, LatencyModel::A, &cfg()).unwrap();
    let lo = latency_any_k(&oracle, &oa.loads, LatencyModel::A, &cfg()).unwrap();
    let rel = (lc.mean() - lo.mean()).abs() / lo.mean();
    assert!(rel < 0.05, "clustering penalty {rel}");
}

#[test]
fn fig2_and_fig6_analytic_shapes() {
    // Quick analytic regressions: T* = Θ(1/N) collapse and the Fig-6 rate
    // anchors (≈1/2 mid-band, ≈0.99 at q = 10^1.5).
    let f2 = figures::generate(2, &FigureOpts::quick()).unwrap();
    let a = &f2.series[0].points;
    let b = &f2.series[2].points;
    for (pa, pb) in a.iter().zip(b) {
        assert!((pa.1 - pb.1).abs() < 1e-9 * pa.1);
    }
    let f6 = figures::generate(6, &FigureOpts::default()).unwrap();
    let last = f6.series[0].points.last().unwrap();
    assert!(last.1 > 0.95);
}
